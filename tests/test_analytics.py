"""Paper Table I and Eq. 1–3 — exact reproduction of every derived column."""

import pytest

from repro.core.analytics import (PAPER_HEADLINE, TABLE_I, TABLE_I_PRINTED,
                                  geomean, table_rows)
from repro.core.kernels_isa import KERNELS, baseline_trace, copift_schedule


class TestTableI:
    @pytest.mark.parametrize("name", list(TABLE_I))
    def test_derived_columns_match_paper(self, name):
        """TI, I', S'', S' as printed in Table I (paper rounds to 2 dp,
        except logf S'=1.6 and expf TI=0.83)."""
        k = TABLE_I[name]
        p = TABLE_I_PRINTED[name]
        assert k.thread_imbalance == pytest.approx(p["ti"], abs=0.005)
        assert k.i_prime == pytest.approx(p["i_prime"], abs=0.005)
        assert k.s_double_prime == pytest.approx(p["s_pp"], abs=0.005)
        assert k.s_prime == pytest.approx(p["s_prime"], abs=0.005)

    def test_equation3_identity(self):
        """Eq. 3 uses a+b = max(a,b)+min(a,b): S'' == 1+TI for any counts."""
        for k in TABLE_I.values():
            a, b = k.n_int_base, k.n_fp_base
            assert (a + b) / max(a, b) == pytest.approx(
                1 + min(a, b) / max(a, b))

    def test_ordering_by_expected_speedup(self):
        rows = table_rows()
        s = [r["s_prime"] for r in rows]
        assert s == sorted(s, reverse=True)
        assert rows[0]["kernel"] == "expf"          # S' = 2.21, top row

    @pytest.mark.parametrize("name", KERNELS)
    def test_our_traces_reproduce_counts(self, name):
        """The instruction-level transcriptions in kernels_isa must have
        exactly the Table I counts (this is the contract that keeps the
        timing/energy models honest)."""
        row = TABLE_I[name]
        base = baseline_trace(name)
        cft = copift_schedule(name)
        assert base.n_int == row.n_int_base
        assert base.n_fp == row.n_fp_base
        assert cft.n_int == row.n_int_copift
        assert cft.n_fp == row.n_fp_copift

    def test_isa_extension_requirements(self):
        """Kernels marked *† in Table I use the cft.* custom-1 opcodes; expf
        (unmarked) must use none."""
        for name in KERNELS:
            cft = copift_schedule(name)
            ops = {i.opcode for b in cft.fp_bodies for i in b}
            uses_ext = any(o.startswith("cft.") for o in ops)
            needs = TABLE_I[name].needs_fcvt_d_w or TABLE_I[name].needs_flt_d
            assert uses_ext == needs, name

    def test_geomean(self):
        assert geomean([2.0, 8.0]) == pytest.approx(4.0)
        assert geomean([1.47]) == pytest.approx(1.47)


def test_headline_constants_present():
    for key in ("geomean_speedup", "peak_ipc", "geomean_energy_saving"):
        assert key in PAPER_HEADLINE
