"""Facade invariants (``repro.api``).

THE contract: the facade's single ``evaluate`` code path reproduces the
paper-calibrated numbers *bit-for-bit* — ``Target.single_pe()`` equals
the single-PE machinery, every scheduling strategy collapses onto
block-cyclic on uniform cores, and the historical result classes are
aliases of the one ``Report``.  (The pre-facade shims were deleted after
PR 8; their parity contracts live on as facade-internal invariants here
and as the 1-cluster system reduction in ``tests/test_system_model.py``.)
Plus: the registry resolves every historical name, ``config`` overrides
are scoped and race-free, the ``Tuner`` shares one cache across its
methods, and per-island block tuning never scores worse than the
shared-block plan under the same power cap.
"""

import threading
import warnings

import pytest

from repro import api
from repro.cluster.scheduler import STRATEGIES
from repro.core.analytics import TABLE_I
from repro.core.energy import evaluate_energy
from repro.core.kernels_isa import KERNELS, baseline_trace, copift_schedule
from repro.core.timing import evaluate_kernel

#: Every numeric/structural field of a Report two evaluations must agree
#: on for "bit-for-bit" (``strategy`` is a label, compared separately).
_REPORT_FIELDS = (
    "name", "core_points", "block", "total_blocks",
    "total_elems", "blocks_per_core", "ref_freq_ghz", "cycles_base",
    "cycles_copift", "instrs_base", "instrs_copift", "extra_contention",
    "imbalance", "dma_bound", "dma_utilization", "power_base_mw",
    "power_copift_mw")


def _assert_reports_identical(a, b):
    for f in _REPORT_FIELDS:
        assert getattr(a, f) == getattr(b, f), f


class TestSinglePeReduction:
    """Target.single_pe() is the paper's setting: the facade must equal
    the calibrated single-PE machinery exactly (the independent ground
    truth, not merely the old cluster code)."""

    @pytest.mark.parametrize("name", KERNELS)
    def test_single_pe_bit_for_bit(self, name):
        pe = evaluate_kernel(name, baseline_trace(name),
                             copift_schedule(name), TABLE_I[name].max_block)
        r = api.evaluate(name, api.Target.single_pe())
        assert r.speedup == pe.speedup
        assert r.ipc_copift == pe.ipc_copift
        assert r.ipc_base == pe.ipc_base
        assert r.cycles_copift == pe.cycles_copift
        assert r.cycles_base == pe.cycles_base
        en = evaluate_energy(name)
        assert r.energy_saving == en.energy_saving
        assert r.power_ratio == en.power_ratio
        assert r.extra_contention == 0.0

    def test_homogeneous_cycles_are_exact_ints(self):
        r = api.evaluate("expf", api.Target.homogeneous(n_cores=8))
        assert isinstance(r.cycles_copift, int)
        assert isinstance(r.cycles_base, int)


class TestFacadeParity:
    """The one evaluate path is internally consistent bit-for-bit: on
    uniform cores every weighted strategy collapses onto block-cyclic,
    and the constructors that claim equivalence deliver it exactly."""

    @pytest.mark.parametrize("strategy", STRATEGIES)
    @pytest.mark.parametrize("name", KERNELS)
    def test_uniform_cores_strategy_invariant(self, name, strategy):
        r = api.evaluate(
            name, api.Target.homogeneous(n_cores=8).with_strategy(strategy))
        base = api.evaluate(name, api.Target.homogeneous(n_cores=8))
        _assert_reports_identical(r, base)

    @pytest.mark.parametrize("name", KERNELS)
    def test_single_pe_is_the_one_core_homogeneous_target(self, name):
        r = api.evaluate(name, api.Target.single_pe())
        base = api.evaluate(name, api.Target.homogeneous(n_cores=1))
        _assert_reports_identical(r, base)

    def test_result_classes_are_report_aliases(self):
        from repro.cluster import ClusterKernelResult, HetClusterResult
        assert ClusterKernelResult is api.Report
        assert HetClusterResult is api.Report

    def test_metric_properties_defined_once(self):
        """The drift-prone copy-pasted properties are gone: both historical
        classes resolve every metric from the shared mixin."""
        for prop in ("speedup", "ipc_base", "ipc_copift", "power_ratio",
                     "energy_saving", "time_us", "cycles_per_elem",
                     "energy_pj_per_elem"):
            assert getattr(api.Report, prop) is getattr(api.ReportMetrics,
                                                        prop)


class TestShimsGone:
    """The deprecation window closed: the pre-facade names no longer
    exist anywhere (importing them is an error, not a warning)."""

    def test_cluster_shims_removed(self):
        import repro.cluster as cluster
        for name in ("evaluate_cluster", "evaluate_cluster_het"):
            assert not hasattr(cluster, name)
            assert name not in cluster.__all__

    def test_kernel_setter_shims_removed(self):
        import repro.kernels as kernels
        from repro.kernels import ops as kops
        for name in ("set_default_impl", "enable_tuned_defaults"):
            assert not hasattr(kops, name)
            assert not hasattr(kernels, name)
            assert name not in kernels.__all__


class TestTarget:
    def test_single_pe_is_one_core_cluster(self):
        t = api.Target.single_pe()
        assert t.n_cores == 1 and not t.is_heterogeneous
        assert t.core_points == (api.NOMINAL_POINT,)

    def test_homogeneous_preserves_shared_resources(self):
        cfg = api.ClusterConfig(tcdm_banks=64)
        t = api.Target.homogeneous(n_cores=4, cluster=cfg)
        assert t.cluster.tcdm_banks == 64 and t.n_cores == 4

    def test_heterogeneous_from_spec_string(self):
        t = api.Target.heterogeneous("2@1.45GHz@1.00V,6@0.50GHz@0.60V")
        assert t.is_heterogeneous and t.n_cores == 8
        assert t.strategy == "lpt"
        assert len(set(t.core_points)) == 2

    def test_rejects_unknown_strategy(self):
        with pytest.raises(ValueError, match="strategy"):
            api.Target(strategy="round_robin")

    def test_report_point_property(self):
        hom = api.evaluate("expf", api.Target.homogeneous(n_cores=2))
        assert hom.point == api.NOMINAL_POINT
        het = api.evaluate("expf",
                           api.Target.heterogeneous("1@1.45GHz@1.00V,"
                                                    "1@0.50GHz@0.60V"))
        with pytest.raises(ValueError, match="core_points"):
            het.point


class TestKernelRegistry:
    def test_every_historical_name_resolves(self):
        for name in KERNELS:
            assert api.kernel(name).isa_name == name
        assert api.kernel("montecarlo").isa_name == "pi_xoshiro128p"
        assert api.kernel("prng").tunable
        assert not api.kernel("prng").simulatable

    def test_unknown_kernel_names_known_set(self):
        with pytest.raises(KeyError, match="montecarlo"):
            api.kernel("nope")

    def test_tuner_only_kernel_rejected_by_evaluate(self):
        with pytest.raises(ValueError, match="tuner-only"):
            api.evaluate("softmax", api.Target.single_pe())

    def test_register_kernel_hook_and_overwrite_guard(self):
        spec = api.KernelSpec("user_exp", isa_name="expf",
                              aliases=("my_exp",))
        try:
            api.register_kernel(spec)
            assert api.kernel("my_exp") is spec
            # The registered kernel evaluates through its ISA binding.
            r = api.evaluate("user_exp", api.Target.single_pe())
            assert r.name == "expf"
            with pytest.raises(ValueError, match="overwrite=True"):
                api.register_kernel(api.KernelSpec("user_exp"))
            api.register_kernel(api.KernelSpec("user_exp", isa_name="logf",
                                               aliases=("my_exp",)),
                                overwrite=True)
            assert api.kernel("user_exp").isa_name == "logf"
        finally:
            from repro.api import registry as _k
            _k._REGISTRY.pop("user_exp", None)
            _k._ALIASES.pop("my_exp", None)

    def test_spec_binds_max_block(self):
        assert api.kernel("expf").max_block == TABLE_I["expf"].max_block

    def test_overwrite_reclaims_alias_names(self):
        """Regression: registering over an existing *alias* must purge the
        stale alias mapping, or kernel() would resolve past the new spec."""
        from repro.api import registry as _k
        snap_reg, snap_ali = dict(_k._REGISTRY), dict(_k._ALIASES)
        try:
            spec = api.KernelSpec("montecarlo", isa_name="pi_lcg")
            api.register_kernel(spec, overwrite=True)
            assert api.kernel("montecarlo") is spec
        finally:
            _k._REGISTRY.clear(); _k._REGISTRY.update(snap_reg)
            _k._ALIASES.clear(); _k._ALIASES.update(snap_ali)


class TestParseIslandsErrors:
    """Satellite: errors name the offending token and the grammar."""

    @pytest.mark.parametrize("spec,needle", [
        ("", "empty island spec"),
        ("2@1.45GHz@1.00V,,6@0.50GHz@0.60V", "island 2"),
        ("two@1.00GHz@0.80V", "'two' is not an integer"),
        ("2", "no '@<point-name>' part"),
        ("0@1.00GHz@0.80V", "core count must be >= 1"),
        ("2@9.99GHz@9.99V", "'9.99GHz@9.99V' is not in the ladder"),
    ])
    def test_errors_name_token_and_grammar(self, spec, needle):
        with pytest.raises(ValueError) as ei:
            api.parse_islands(spec, api.SNITCH_CLUSTER)
        assert needle in str(ei.value)
        if spec:
            assert "<count>@<point-name>" in str(ei.value)


class TestConfigContextManager:
    """Satellite: the mutable kernel globals became scoped ContextVars."""

    def test_scoped_and_restored(self):
        from repro.kernels import ops as kops
        assert kops.current_impl() == "auto"
        with api.config(impl="reference", tuned_defaults=True):
            assert kops.current_impl() == "reference"
            assert kops.tuned_defaults_enabled()
            with api.config(impl="pallas"):
                assert kops.current_impl() == "pallas"
                assert kops.tuned_defaults_enabled()
            assert kops.current_impl() == "reference"
        assert kops.current_impl() == "auto"
        assert not kops.tuned_defaults_enabled()

    def test_restores_on_error(self):
        from repro.kernels import ops as kops
        with pytest.raises(RuntimeError):
            with api.config(impl="reference"):
                raise RuntimeError("boom")
        assert kops.current_impl() == "auto"

    def test_rejects_unknown_impl(self):
        with pytest.raises(ValueError, match="unknown impl"):
            with api.config(impl="cuda"):
                pass  # pragma: no cover

    def test_persistent_setter_visible_across_threads(self):
        """Regression: ServeEngine(autotune=True) sets the tuned-defaults
        *process-wide* default in __init__; generate() may run on another
        thread and must still see it (ContextVars alone would not)."""
        from repro.kernels import ops as kops
        seen = {}
        try:
            kops.set_tuned_defaults(True)
            th = threading.Thread(
                target=lambda: seen.update(
                    tuned=kops.tuned_defaults_enabled()))
            th.start(); th.join(5)
            assert seen["tuned"] is True
        finally:
            kops.set_tuned_defaults(False)

    def test_concurrent_threads_do_not_race(self):
        """The failure mode the satellite targets: an override in one
        thread must be invisible to a concurrently running benchmark."""
        from repro.kernels import ops as kops
        inside = threading.Event()
        release = threading.Event()
        seen = {}

        def override_thread():
            with api.config(impl="pallas"):
                inside.set()
                release.wait(5)

        def observer_thread():
            inside.wait(5)
            seen["impl"] = kops.current_impl()
            release.set()

        t1 = threading.Thread(target=override_thread)
        t2 = threading.Thread(target=observer_thread)
        t1.start(); t2.start()
        t1.join(5); t2.join(5)
        assert seen["impl"] == "auto"


class TestTuner:
    def test_methods_share_one_cache(self, tmp_path):
        from repro.tune import TuneCache
        cache = TuneCache(tmp_path / "cache.json")
        tuner = api.Tuner(cache=cache)
        tuner.block("prng")
        tuner.plan("prng")
        assert tuner.cache is cache
        assert len(cache) == 2          # both searches landed in one store
        assert tuner.block("prng").from_cache

    def test_plan_matches_legacy_tune(self):
        from repro.tune import tune
        legacy = tune("prng", cache=False)
        new = api.Tuner(cache=False).plan("prng")
        assert new.best == legacy.best
        assert new.best_cost == legacy.best_cost

    def test_operating_point_matches_legacy(self):
        from repro.tune import select_operating_point
        legacy = select_operating_point("expf", n_cores=8,
                                        power_cap_mw=350.0, cache=False)
        new = api.Tuner(api.Target.homogeneous(power_cap_mw=350.0),
                        cache=False).operating_point("expf", n_cores=8)
        assert new.best == legacy.best
        assert new.best_cost == legacy.best_cost

    def test_accepts_spec_objects_and_aliases(self):
        tuner = api.Tuner(cache=False)
        by_alias = tuner.block("montecarlo")
        by_spec = tuner.block(api.kernel("pi_xoshiro128p"))
        assert by_alias.workload == by_spec.workload == "montecarlo"

    def test_bound_objective_applies_to_every_method(self):
        """Regression: Tuner(objective=...) must bind operating_point too,
        not just plan/block."""
        tuner = api.Tuner(api.Target.homogeneous(power_cap_mw=350.0),
                          objective="edp", cache=False)
        assert tuner.plan("prng").objective == "edp"
        assert tuner.operating_point("prng").objective == "edp"
        # Default Tuner keeps the per-method historical defaults.
        plain = api.Tuner(cache=False)
        assert plain.plan("prng").objective == "cycles"
        assert plain.operating_point("prng").objective == "energy"


class TestPerIslandBlocks:
    """Satellite + acceptance: per-island block tuning never scores worse
    than the shared-block plan under the same power cap."""

    def test_uniform_island_blocks_canonicalize_to_shared(self):
        from repro.tune import Candidate, evaluate, get_workload
        w = get_workload("expf")
        shared = evaluate(w, Candidate(block=64, n_cores=8,
                                       islands=("1.45GHz@1.00V",
                                                "0.50GHz@0.60V"),
                                       strategy="lpt"))
        uniform = evaluate(w, Candidate(block=w.max_block, n_cores=8,
                                        islands=("1.45GHz@1.00V",
                                                 "0.50GHz@0.60V"),
                                        strategy="lpt",
                                        island_blocks=(64, 64)))
        assert uniform == shared

    def test_island_blocks_validation(self):
        from repro.tune import Candidate, evaluate, get_workload
        w = get_workload("expf")
        with pytest.raises(ValueError, match="one-for-one"):
            evaluate(w, Candidate(block=64, n_cores=8,
                                  islands=("1.00GHz@0.80V",),
                                  island_blocks=(64, 32)))
        with pytest.raises(ValueError, match="outside"):
            evaluate(w, Candidate(block=64, n_cores=8,
                                  islands=("1.00GHz@0.80V",
                                           "0.50GHz@0.60V"),
                                  island_blocks=(64, w.max_block + 1)))

    @pytest.mark.parametrize("cap", [None, 250.0])
    @pytest.mark.parametrize("name", ["expf", "softmax"])
    def test_never_worse_than_shared_block(self, name, cap):
        from repro.tune.cost import objective_value
        tuner = api.Tuner(api.Target.homogeneous(power_cap_mw=cap),
                          cache=False)
        shared = tuner.operating_point(name, heterogeneous=True,
                                       objective="edp")
        refined = tuner.operating_point(name, heterogeneous=True,
                                        objective="edp",
                                        per_island_blocks=True)
        assert objective_value(refined.best_cost, "edp") \
            <= objective_value(shared.best_cost, "edp")
        if cap is not None and shared.best_cost.feasible:
            assert refined.best_cost.power_mw <= cap

    def test_candidate_round_trips_island_blocks(self):
        import json

        from repro.tune import Candidate
        c = Candidate(block=64, n_cores=8,
                      islands=("1.45GHz@1.00V", "0.50GHz@0.60V"),
                      strategy="lpt", island_blocks=(128, 32))
        back = Candidate.from_dict(json.loads(json.dumps(c.to_dict())))
        assert back == c and isinstance(back.island_blocks, tuple)

    def test_from_dict_tolerates_old_payloads(self):
        from repro.tune import Candidate
        old = Candidate(block=64).to_dict()
        del old["island_blocks"]        # a pre-facade cache payload
        assert Candidate.from_dict(old) == Candidate(block=64)


class TestFacadeHelpers:
    def test_compare_strategies_keys(self):
        t = api.Target.heterogeneous("1@1.45GHz@1.00V,1@0.50GHz@0.60V")
        res = api.compare_strategies("expf", t, total_blocks=6)
        assert set(res) == set(STRATEGIES)
        assert all(isinstance(r, api.Report) for r in res.values())

    def test_headline_matches_cluster_export(self):
        from repro.cluster import headline as cluster_headline
        assert api.headline is cluster_headline

    def test_scaling_helpers_do_not_warn(self):
        """The still-supported analytics helpers migrated internally: no
        DeprecationWarning leaks from them."""
        from repro.cluster import strong_scaling, weak_scaling
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            weak_scaling("poly_lcg", cores=(1, 2))
            strong_scaling("poly_lcg", cores=(1, 2), total_blocks=4)
