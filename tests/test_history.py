"""``repro.obs.history`` — the append-only metric store and its
rolling-baseline regression gate, plus the writers that feed it
(``benchmarks/run.py --history``, ``obs_bench --history``) and the HTML
report that reads it.

The contract: appends are one JSONL line per run (SHA + timestamp +
source + flat metrics); reads tolerate corruption; the gate compares
each source's newest record against the *median* of up to ``window``
prior records, with per-metric direction rules — and only HARD
(>= 10 %) moves of deterministic metrics fail a build.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.obs import history

REPO = Path(__file__).resolve().parent.parent


def _append_run(path, speedup, cycles=1000.0, seconds=1.0, ts=0.0,
                source="bench"):
    return history.append_record(
        {"tune/expf/speedup": speedup, "fig2/expf/cycles": cycles,
         "perf/oracle/batch_seconds": seconds},
        source=source, path=path, sha="deadbeef", ts=ts)


class TestStore:
    def test_append_read_roundtrip(self, tmp_path):
        p = tmp_path / "h.jsonl"
        rec = history.append_record({"a/b": 1.5, "c/d": 2},
                                    source="s", path=p, sha="abc", ts=42.0)
        assert rec["schema"] == history.SCHEMA
        assert rec["metrics"] == {"a/b": 1.5, "c/d": 2.0}
        back = history.read_history(p)
        assert back == [rec]
        assert history.read_history.skipped == 0

    def test_append_is_append_only(self, tmp_path):
        p = tmp_path / "h.jsonl"
        for i in range(3):
            _append_run(p, 1.5, ts=float(i))
        recs = history.read_history(p)
        assert [r["ts"] for r in recs] == [0.0, 1.0, 2.0]

    def test_corrupt_and_truncated_lines_skipped(self, tmp_path):
        p = tmp_path / "h.jsonl"
        _append_run(p, 1.5, ts=0.0)
        with open(p, "a") as f:
            f.write('{"truncated": \n')          # interrupted write
            f.write("not json at all\n")
            f.write('{"no_metrics_key": 1}\n')
            f.write("\n")                         # blank: ignored, not counted
        _append_run(p, 1.4, ts=1.0)
        recs = history.read_history(p)
        assert len(recs) == 2
        assert history.read_history.skipped == 3

    def test_missing_file_is_empty_history(self, tmp_path):
        assert history.read_history(tmp_path / "nope.jsonl") == []

    def test_source_filter(self, tmp_path):
        p = tmp_path / "h.jsonl"
        _append_run(p, 1.5, source="a")
        _append_run(p, 1.4, source="b")
        assert len(history.read_history(p, source="a")) == 1

    def test_path_resolution_env_var(self, tmp_path, monkeypatch):
        assert history.history_path("x.jsonl") == "x.jsonl"
        monkeypatch.setenv(history.ENV_VAR, str(tmp_path / "env.jsonl"))
        assert history.history_path() == str(tmp_path / "env.jsonl")
        monkeypatch.delenv(history.ENV_VAR)
        assert history.history_path() == history.DEFAULT_FILENAME


class TestFlattenSnapshot:
    def test_keys_mirror_diff_identity(self):
        snap = {"schema": 1, "sections": {
            "fig2": {"lines": ["fig2.expf,speedup,1.50",
                               "fig2.expf,speedup,1.40",   # repeated key
                               "fig2.logf,ipc,0.9,1.1"]},
            "perf": {"lines": [], "error": "skipped"},
        }}
        flat = history.flatten_snapshot(snap)
        assert flat == {
            "fig2/fig2.expf,speedup/c2": 1.50,
            "fig2/fig2.expf,speedup@1/c2": 1.40,
            "fig2/fig2.logf,ipc/c2": 0.9,
            "fig2/fig2.logf,ipc/c3": 1.1,
        }

    def test_header_line_names_columns(self):
        """A section whose first line is a pure CSV header (table1, fig2,
        tune, obs all emit one) names its numeric columns after the
        header tokens — that's what lets the direction rules recognize
        cycles/speedup metrics in real snapshots."""
        snap = {"sections": {"tune": {"lines": [
            "tune.kernel,block,default_cycles,predicted_speedup",
            "tune.expf,157,744552,1.0003",
            "tune.softmax,136,746597,1.0018,9.9",  # extra col: cN fallback
        ]}}}
        flat = history.flatten_snapshot(snap)
        assert flat == {
            "tune/tune.expf/block": 157.0,
            "tune/tune.expf/default_cycles": 744552.0,
            "tune/tune.expf/predicted_speedup": 1.0003,
            "tune/tune.softmax/block": 136.0,
            "tune/tune.softmax/default_cycles": 746597.0,
            "tune/tune.softmax/predicted_speedup": 1.0018,
            "tune/tune.softmax/c4": 9.9,
        }
        assert history.metric_direction(
            "tune/tune.expf/default_cycles") == "higher_worse"
        assert history.metric_direction(
            "tune/tune.expf/predicted_speedup") == "lower_worse"

    def test_non_finite_values_dropped(self):
        snap = {"sections": {"s": {"lines": ["k,inf,nan,2.0"]}}}
        assert history.flatten_snapshot(snap) == {"s/k/c3": 2.0}

    def test_percent_tokens_are_data_not_identity(self):
        """``+29.5%``-style tokens (the obs section emits them) parse as
        numeric columns — left in the key they would mint a fresh metric
        name every run, so the overhead trend could never be checked."""
        snap = {"sections": {"obs": {"lines": [
            "obs.overhead,mode,seconds,overhead_vs_reference",
            "obs.overhead,disabled,0.703,+29.5%",
        ]}}}
        assert history.flatten_snapshot(snap) == {
            "obs/obs.overhead,disabled/seconds": 0.703,
            "obs/obs.overhead,disabled/overhead_vs_reference": 29.5,
        }

    def test_append_snapshot_records_sections(self, tmp_path):
        p = tmp_path / "h.jsonl"
        snap = {"sections": {"fig2": {"lines": ["fig2.expf,speedup,1.5"]}}}
        rec = history.append_snapshot(snap, path=p)
        assert rec["source"] == "benchmarks.run"
        assert rec["meta"]["sections"] == ["fig2"]


class TestDirectionRules:
    @pytest.mark.parametrize("name,want", [
        ("perf/oracle/batch_seconds", "advisory"),
        ("perf/oracle/candidates_per_sec", "advisory"),
        ("tune/measured_default_us/c3", "advisory"),
        ("obs_bench/disabled_overhead", "advisory"),
        ("tune/expf/speedup", "lower_worse"),
        ("fig2/expf/ipc", "lower_worse"),
        ("tune/point/saving_vs_nominal", "lower_worse"),
        ("fig2/expf/cycles", "higher_worse"),
        ("table1/expf/energy_uj", "higher_worse"),
        ("cluster/expf/power_mw", "higher_worse"),
        # Resilience rows (benchmarks/resilience_bench.py): losses,
        # retries, kills and failovers must not creep up; the completed
        # fraction must not fall — even on the failover(...) policy row,
        # whose name would otherwise first-match nothing useful.
        ("resilience/resilience.policy.static/lost", "higher_worse"),
        ("resilience/resilience.policy.failover(static+1)/retried",
         "higher_worse"),
        ("resilience/resilience.policy.static/batches_killed",
         "higher_worse"),
        ("resilience/resilience.policy.failover(static+1)/failovers",
         "higher_worse"),
        ("resilience/resilience.policy.failover(static+1)/completed_frac",
         "lower_worse"),
        ("something/else/entirely", "advisory"),
    ])
    def test_first_match_classification(self, name, want):
        assert history.metric_direction(name) == want


class TestMemoryFallback:
    """An unwritable store degrades to in-process records + one warning
    (the ``tune.cache`` contract: history observes, it never gates)."""

    def _unwritable(self, tmp_path):
        blocker = tmp_path / "blocker"
        blocker.write_text("not a directory")
        return str(blocker / "hist.jsonl")   # open() -> NotADirectoryError

    def test_append_warns_once_and_keeps_records(self, tmp_path):
        import warnings
        bad = self._unwritable(tmp_path)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            history.append_record({"m": 1.0}, source="t", path=bad, ts=1.0)
            history.append_record({"m": 2.0}, source="t", path=bad, ts=2.0)
        runtime = [w for w in caught
                   if issubclass(w.category, RuntimeWarning)]
        assert len(runtime) == 1
        assert "in-memory" in str(runtime[0].message)
        recs = history.read_history(bad, source="t")
        assert [r["metrics"]["m"] for r in recs] == [1.0, 2.0]

    def test_memory_records_feed_regression_detection(self, tmp_path):
        import warnings
        bad = self._unwritable(tmp_path)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            for i, speedup in enumerate((2.0, 2.0, 2.0, 1.0)):
                history.append_record({"tune/expf/speedup": speedup},
                                      source="t", path=bad, ts=float(i))
        doc = history.detect_regressions(path=bad)
        assert not doc["ok"]
        assert doc["regressions"][0]["metric"] == "tune/expf/speedup"

    def test_writable_path_untouched_by_fallback(self, tmp_path):
        p = tmp_path / "hist.jsonl"
        history.append_record({"m": 1.0}, source="t", path=p, ts=1.0)
        assert str(p) not in history._MEMORY
        assert len(history.read_history(p)) == 1


class TestDetectRegressions:
    def test_needs_two_records(self, tmp_path):
        p = tmp_path / "h.jsonl"
        _append_run(p, 1.5)
        doc = history.detect_regressions(path=p)
        assert doc["ok"] and doc["checked"] == 0

    def test_hard_speedup_drop_fails(self, tmp_path):
        p = tmp_path / "h.jsonl"
        for i in range(4):
            _append_run(p, 1.5, ts=float(i))
        _append_run(p, 1.25, ts=9.0)              # -16.7% vs median 1.5
        doc = history.detect_regressions(path=p)
        assert not doc["ok"]
        (r,) = [r for r in doc["regressions"] if r["severity"] == "hard"]
        assert r["metric"] == "tune/expf/speedup"
        assert r["direction"] == "lower_worse"
        assert r["rel_delta"] == pytest.approx(-1 / 6)

    def test_soft_band_reports_without_gating(self, tmp_path):
        p = tmp_path / "h.jsonl"
        for i in range(4):
            _append_run(p, 1.5, ts=float(i))
        _append_run(p, 1.5, cycles=1040.0, ts=9.0)   # cycles +4%: soft
        doc = history.detect_regressions(path=p)
        assert doc["ok"]
        assert [r["severity"] for r in doc["regressions"]] == ["soft"]

    def test_advisory_metrics_never_gate(self, tmp_path):
        p = tmp_path / "h.jsonl"
        for i in range(4):
            _append_run(p, 1.5, seconds=1.0, ts=float(i))
        _append_run(p, 1.5, seconds=40.0, ts=9.0)    # +3900% wall time
        doc = history.detect_regressions(path=p)
        assert doc["ok"]
        assert [r["severity"] for r in doc["regressions"]] == ["info"]

    def test_improvements_counted_not_flagged(self, tmp_path):
        p = tmp_path / "h.jsonl"
        for i in range(4):
            _append_run(p, 1.5, ts=float(i))
        _append_run(p, 2.0, cycles=800.0, ts=9.0)
        doc = history.detect_regressions(path=p)
        assert doc["ok"] and not doc["regressions"]
        assert doc["improvements"] == 2

    def test_median_baseline_resists_one_bad_run(self, tmp_path):
        p = tmp_path / "h.jsonl"
        for i, s in enumerate((1.5, 1.5, 0.1, 1.5)):   # one poisoned run
            _append_run(p, s, ts=float(i))
        _append_run(p, 1.5, ts=9.0)
        doc = history.detect_regressions(path=p)
        assert doc["ok"] and not doc["regressions"]    # median still 1.5

    def test_window_bounds_the_baseline(self, tmp_path):
        p = tmp_path / "h.jsonl"
        for i in range(6):
            _append_run(p, 3.0, ts=float(i))           # ancient glory
        for i in range(8):
            _append_run(p, 1.5, ts=10.0 + i)           # recent normal
        _append_run(p, 1.5, ts=99.0)
        doc = history.detect_regressions(path=p, window=8)
        assert doc["ok"] and not doc["regressions"]

    def test_sources_isolated(self, tmp_path):
        p = tmp_path / "h.jsonl"
        for i in range(3):
            _append_run(p, 1.5, ts=float(i), source="a")
        _append_run(p, 99.0, ts=5.0, source="b")       # one record: no base
        _append_run(p, 1.5, ts=6.0, source="a")
        doc = history.detect_regressions(path=p)
        assert doc["ok"] and doc["sources"] == {"a": 4, "b": 1}

    def test_new_metric_skipped_zero_baseline_inf(self, tmp_path):
        p = tmp_path / "h.jsonl"
        history.append_record({"x/cycles": 0.0}, source="s", path=p, ts=0.0)
        history.append_record({"x/cycles": 0.0, "y/cycles": 5.0},
                              source="s", path=p, ts=1.0)
        doc = history.detect_regressions(path=p)
        assert doc["ok"] and doc["checked"] == 1       # y is new: skipped
        history.append_record({"x/cycles": 1.0}, source="s", path=p, ts=2.0)
        doc = history.detect_regressions(path=p)
        assert not doc["ok"]                           # 0 -> 1 is inf, hard
        assert doc["regressions"][0]["rel_delta"] == float("inf")

    def test_threshold_validation(self):
        with pytest.raises(ValueError, match="soft"):
            history.detect_regressions([], soft=0.2, hard=0.1)

    def test_format_lines(self, tmp_path):
        p = tmp_path / "h.jsonl"
        for i in range(3):
            _append_run(p, 1.5, ts=float(i))
        _append_run(p, 1.0, ts=9.0)
        lines = history.format_regressions(history.detect_regressions(path=p))
        assert lines[0].startswith("history.checked,")
        assert any(ln.startswith("history.hard,") for ln in lines)


class TestCli:
    def test_check_exits_1_on_hard(self, tmp_path, capsys):
        p = tmp_path / "h.jsonl"
        for i in range(3):
            _append_run(p, 1.5, ts=float(i))
        _append_run(p, 1.0, ts=9.0)
        with pytest.raises(SystemExit) as ei:
            history.main(["--path", str(p), "--check"])
        assert ei.value.code == 1
        assert "history.fail" in capsys.readouterr().out

    def test_check_clean_exits_0(self, tmp_path, capsys):
        p = tmp_path / "h.jsonl"
        for i in range(3):
            _append_run(p, 1.5, ts=float(i))
        history.main(["--path", str(p), "--check"])
        assert "history.clean" in capsys.readouterr().out

    def test_store_summary(self, tmp_path, capsys):
        p = tmp_path / "h.jsonl"
        _append_run(p, 1.5, source="bench")
        history.main(["--path", str(p)])
        out = capsys.readouterr().out
        assert "history.store," in out and "history.source,bench," in out


class TestWriters:
    def test_run_py_history_appends_and_gates(self, tmp_path):
        """`benchmarks.run --history --check-regressions` end to end:
        appends the snapshot's metrics and runs the gate (clean here —
        a single record has no baseline)."""
        p = tmp_path / "h.jsonl"
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.run",
             "--sections", "table1", "--json", str(tmp_path / "s.json"),
             "--history", str(p), "--check-regressions"],
            capture_output=True, text=True, cwd=REPO, check=True)
        assert "benchmarks.history," in out.stdout
        assert "history.checked,0" in out.stdout
        recs = history.read_history(p)
        assert len(recs) == 1 and recs[0]["source"] == "benchmarks.run"
        assert any(k.startswith("table1/") for k in recs[0]["metrics"])

    def test_check_regressions_requires_history(self):
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--check-regressions"],
            capture_output=True, text=True, cwd=REPO)
        assert out.returncode != 0
        assert "--check-regressions requires --history" in out.stderr

    def test_run_py_hard_regression_fails_build(self, tmp_path):
        """Seed the store with a fabricated too-good baseline for one
        deterministic fig2 speedup metric; the real run must then trip
        the hard gate and exit 1."""
        p = tmp_path / "h.jsonl"
        s1 = tmp_path / "s1.json"
        subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--sections", "fig2",
             "--json", str(s1), "--history", str(p)],
            capture_output=True, text=True, cwd=REPO, check=True)
        real = history.read_history(p)[0]["metrics"]
        name = next(k for k in sorted(real) if k.endswith("/speedup"))
        for i in range(3):  # fabricated history: 40% faster than reality
            history.append_record({name: real[name] * 1.4},
                                  source="benchmarks.run", path=p,
                                  ts=float(i))
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--sections", "fig2",
             "--json", str(tmp_path / "s2.json"),
             "--history", str(p), "--check-regressions"],
            capture_output=True, text=True, cwd=REPO)
        assert out.returncode == 1
        assert "benchmarks.history_fail" in out.stdout
        assert f"history.hard,benchmarks.run,{name}" in out.stdout

    def test_obs_bench_smoke_appends_overhead(self, tmp_path):
        """The history append happens (and is well-formed) regardless of
        the wall-clock gate: with --repeats 1 the 5% overhead check can
        flake under load, and that exit-1 path must *still* have written
        the record first (the trend is most valuable on bad runs).  A
        parity failure, by contrast, is a real bug and fails here."""
        p = tmp_path / "h.jsonl"
        out = subprocess.run(
            [sys.executable, str(REPO / "benchmarks" / "obs_bench.py"),
             "--smoke", "--repeats", "1", "--history", str(p)],
            capture_output=True, text=True, cwd=REPO,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
        assert "obs.fail,observed results diverged" not in out.stdout
        assert out.returncode == 0 or "overhead" in out.stdout.splitlines()[-1], \
            out.stdout + out.stderr
        assert "obs.history," in out.stdout
        (rec,) = history.read_history(p)
        assert rec["source"] == "obs_bench"
        assert set(rec["metrics"]) == {
            "reference_seconds", "disabled_seconds", "enabled_seconds",
            "disabled_overhead", "enabled_overhead"}
        assert rec["meta"]["parity"]
        assert rec["meta"]["overhead_ok"] == (out.returncode == 0)


class TestHtmlReport:
    def test_save_report_self_contained(self, tmp_path):
        from repro import api, obs
        from repro.obs.report import save_report
        with obs.session() as sess:
            api.evaluate("expf", api.Target.homogeneous(n_cores=2))
        p = tmp_path / "h.jsonl"
        for i, s in enumerate((1.5, 1.5, 1.5, 1.2)):
            _append_run(p, s, ts=float(i))
        out = tmp_path / "r.html"
        save_report(out, trace=sess.recorder, history=p)
        html = out.read_text()
        assert html.lstrip().lower().startswith("<!doctype html>")
        assert "<svg" in html                      # timeline + sparklines
        assert "Metric trends" in html
        assert "tune/expf/speedup" in html
        assert "src=" not in html and "href=" not in html  # self-contained

    def test_report_cli_writes_and_exits_0(self, tmp_path):
        from repro.obs.report import main
        p = tmp_path / "h.jsonl"
        for i in range(2):
            _append_run(p, 1.5, ts=float(i))
        out = tmp_path / "r.html"
        assert main(["expf", "--cores", "2", "--history", str(p),
                     "--out", str(out)]) == 0
        assert out.stat().st_size > 10_000

    def test_terminal_summary_sections(self, tmp_path):
        from repro import api, obs
        from repro.obs.report import terminal_summary
        with obs.session() as sess:
            api.evaluate("expf", api.Target.homogeneous(n_cores=2))
        p = tmp_path / "h.jsonl"
        for i in range(2):
            _append_run(p, 1.5, ts=float(i))
        from repro.obs.history import read_history
        text = terminal_summary(trace=sess.recorder,
                                history=read_history(p))
        assert "issue timeline" in text
        assert "history.checked" in text
