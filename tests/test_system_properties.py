"""Property tests for the manycore layer (``repro.system``): block
conservation through the hierarchical scheduler, the exact reduction of
uniform-cluster assignment onto a single-level ``assign``, and HBM
bandwidth monotonicity in the NoC's water-filling arbiter.

Property-based cases run when ``hypothesis`` is installed (the CI
configuration); example-based cases pin the same invariants on a bare
install.
"""

import math

import pytest

from repro.cluster.scheduler import STRATEGIES, assign
from repro.cluster.topology import SNITCH_CLUSTER
from repro.system import (SystemConfig, assign_system, fair_shares,
                          system_transfer_cycles)
from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

SPEED_LADDER = (0.50, 0.75, 1.00, 1.25, 1.45)


def _cluster_speeds_strategy():
    """1..6 clusters of 1..8 cores each, speeds off the DVFS ladder."""
    core_speeds = st.lists(st.sampled_from(SPEED_LADDER),
                           min_size=1, max_size=8)
    return st.lists(core_speeds, min_size=1, max_size=6)


class TestExamples:
    """Example-based invariants (always run, even without hypothesis)."""

    @pytest.mark.parametrize("strategy", STRATEGIES)
    @pytest.mark.parametrize("n_blocks,clusters", [
        (0, ((1.0,) * 8, (1.0,) * 8)),
        (1, ((0.5, 1.45), (1.0,))),
        (48, ((1.0,) * 8,) * 4),
        (97, ((1.45, 1.45, 0.5), (0.75,) * 5, (1.0, 1.25))),
    ])
    def test_block_conservation_across_clusters(self, strategy, n_blocks,
                                                clusters):
        sa = assign_system(n_blocks, clusters, cluster_strategy=strategy,
                           core_strategy=strategy)
        assert sum(sa.cluster_blocks) == n_blocks
        for share, inner in zip(sa.cluster_blocks, sa.core_assignments):
            assert sum(inner.blocks_per_core) == share
        assert sum(sa.flat.blocks_per_core) == n_blocks

    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_uniform_clusters_reduce_to_single_level(self, strategy):
        """Uniform clusters: the hierarchical split hands out the same
        per-cluster multiset of core loads a flat single-level assign
        would give each cluster's share."""
        clusters = ((1.0,) * 8,) * 4
        sa = assign_system(96, clusters, cluster_strategy=strategy,
                           core_strategy=strategy)
        for share, inner in zip(sa.cluster_blocks, sa.core_assignments):
            flat = assign(share, (1.0,) * 8, strategy)
            assert sorted(inner.blocks_per_core) == \
                sorted(flat.blocks_per_core)

    def test_fair_shares_split_the_budget(self):
        shares = fair_shares((64.0, 64.0, 64.0, 64.0), 64.0)
        assert shares == (16.0,) * 4
        # Narrow streams keep their width; leftover re-splits.
        shares = fair_shares((4.0, 64.0, 64.0), 64.0)
        assert shares[0] == 4.0
        assert shares[1] == shares[2] == 30.0
        assert sum(shares) <= 64.0 + 1e-12

    def test_hbm_monotone_example(self):
        sys16 = SystemConfig.homogeneous(4, SNITCH_CLUSTER,
                                         hbm_bytes_per_cycle=16.0)
        sys64 = sys16.with_hbm(64.0)
        free = sys16.with_hbm(None)
        nbytes = (40192,) * 4
        t16 = system_transfer_cycles(sys16, nbytes)
        t64 = system_transfer_cycles(sys64, nbytes)
        tf = system_transfer_cycles(free, nbytes)
        assert all(b <= a for a, b in zip(t16, t64))
        assert all(b <= a for a, b in zip(t64, tf))


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestProperties:
    """Randomized invariants over block counts x cluster shapes x HBM."""

    @settings(max_examples=150, deadline=None)
    @given(n_blocks=st.integers(min_value=0, max_value=512),
           clusters=_cluster_speeds_strategy(),
           cluster_strategy=st.sampled_from(STRATEGIES),
           core_strategy=st.sampled_from(STRATEGIES))
    def test_block_conservation(self, n_blocks, clusters, cluster_strategy,
                                core_strategy):
        clusters = tuple(tuple(c) for c in clusters)
        sa = assign_system(n_blocks, clusters,
                           cluster_strategy=cluster_strategy,
                           core_strategy=core_strategy)
        assert sum(sa.cluster_blocks) == n_blocks
        for share, inner in zip(sa.cluster_blocks, sa.core_assignments):
            assert sum(inner.blocks_per_core) == share
            assert all(b >= 0 for b in inner.blocks_per_core)
        flat = sa.flat
        assert sum(flat.blocks_per_core) == n_blocks
        assert flat.n_cores == sum(len(c) for c in clusters)

    @settings(max_examples=150, deadline=None)
    @given(n_blocks=st.integers(min_value=0, max_value=512),
           n_clusters=st.integers(min_value=1, max_value=6),
           n_cores=st.integers(min_value=1, max_value=8),
           speed=st.sampled_from(SPEED_LADDER),
           strategy=st.sampled_from(STRATEGIES))
    def test_uniform_reduces_to_single_level(self, n_blocks, n_clusters,
                                             n_cores, speed, strategy):
        clusters = ((speed,) * n_cores,) * n_clusters
        sa = assign_system(n_blocks, clusters, cluster_strategy=strategy,
                           core_strategy=strategy)
        for share, inner in zip(sa.cluster_blocks, sa.core_assignments):
            flat = assign(share, (speed,) * n_cores, strategy)
            assert sorted(inner.blocks_per_core) == \
                sorted(flat.blocks_per_core)

    @settings(max_examples=150, deadline=None)
    @given(widths=st.lists(st.sampled_from((4.0, 16.0, 64.0)),
                           min_size=1, max_size=8),
           hbm_lo=st.floats(min_value=1.0, max_value=256.0),
           scale=st.floats(min_value=1.0, max_value=8.0))
    def test_fair_shares_monotone_in_budget(self, widths, hbm_lo, scale):
        widths = tuple(widths)
        lo = fair_shares(widths, hbm_lo)
        hi = fair_shares(widths, hbm_lo * scale)
        assert all(b >= a - 1e-9 for a, b in zip(lo, hi))
        assert all(s <= w + 1e-9 for s, w in zip(lo, widths))
        assert sum(lo) <= hbm_lo + 1e-9 or sum(widths) <= hbm_lo

    @settings(max_examples=100, deadline=None)
    @given(n_clusters=st.integers(min_value=1, max_value=6),
           blocks_per_cluster=st.integers(min_value=1, max_value=64),
           hbm_lo=st.floats(min_value=2.0, max_value=128.0),
           scale=st.floats(min_value=1.0, max_value=16.0))
    def test_transfer_cycles_monotone_in_hbm(self, n_clusters,
                                             blocks_per_cluster, hbm_lo,
                                             scale):
        """More HBM bandwidth never increases any cluster's transfer
        cycles, and the unconstrained system lower-bounds them all."""
        nbytes = tuple(2512 * blocks_per_cluster for _ in range(n_clusters))
        base = SystemConfig.homogeneous(n_clusters, SNITCH_CLUSTER,
                                        hbm_bytes_per_cycle=hbm_lo)
        lo = system_transfer_cycles(base, nbytes)
        hi = system_transfer_cycles(base.with_hbm(hbm_lo * scale), nbytes)
        free = system_transfer_cycles(base.with_hbm(None), nbytes)
        assert all(b <= a for a, b in zip(lo, hi))
        assert all(f <= b for f, b in zip(free, hi))
        assert all(t >= math.ceil(n / SNITCH_CLUSTER.dma_bytes_per_cycle)
                   for t, n in zip(lo, nbytes))
