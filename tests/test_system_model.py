"""Manycore system-model invariants (``repro.system`` + facade wiring).

THE contract: a 1-cluster ``SystemConfig`` with unconstrained HBM reduces
*bit-for-bit* to the single-cluster ``Report`` — for every simulatable
kernel x scheduling strategy, weak and strong scaling alike.  The system
layer prices clusters through the exact same ``_price_cluster`` middle of
``api.evaluate``, so this is an identity of expression trees, not a
tolerance.  Plus: strong scaling is exactly linear for the compute-only
kernel, the shared-HBM roofline flattens the curve, the tuner's
``n_clusters`` knob sizes the part under a system power cap, the serving
pricer partitions system cores, SLO-aware admission beats tail-drop on an
overloaded trace, and ``benchmarks/run.py`` rejects unknown section names
by name.
"""

import pytest

from repro import api
from repro.cluster.scheduler import STRATEGIES
from repro.cluster.topology import SNITCH_CLUSTER
from repro.core.kernels_isa import KERNELS
from repro.system import (SystemConfig, SystemPoint, evaluate_system,
                          parse_system, select_system_point, system_cost)

#: Every numeric/structural field two Reports must agree on for
#: "bit-for-bit" (mirrors tests/test_api.py).
_REPORT_FIELDS = (
    "name", "core_points", "block", "total_blocks",
    "total_elems", "blocks_per_core", "ref_freq_ghz", "cycles_base",
    "cycles_copift", "instrs_base", "instrs_copift", "extra_contention",
    "imbalance", "dma_bound", "dma_utilization", "power_base_mw",
    "power_copift_mw")


def _assert_reports_identical(a, b):
    for f in _REPORT_FIELDS:
        assert getattr(a, f) == getattr(b, f), f


class TestSingleClusterReduction:
    """The non-negotiable invariant: Target.system(1) with unconstrained
    HBM equals the single-cluster path exactly, field by field."""

    @pytest.mark.parametrize("strategy", STRATEGIES)
    @pytest.mark.parametrize("name", KERNELS)
    def test_weak_scaling_parity(self, name, strategy):
        sys_r = api.evaluate(name, api.Target.system(1, strategy=strategy),
                             blocks_per_core=3)
        one = api.evaluate(name, api.Target(strategy=strategy),
                           blocks_per_core=3)
        _assert_reports_identical(sys_r, one)

    @pytest.mark.parametrize("strategy", STRATEGIES)
    @pytest.mark.parametrize("name", KERNELS)
    def test_strong_scaling_parity(self, name, strategy):
        sys_r = api.evaluate(name, api.Target.system(1, strategy=strategy),
                             total_blocks=48)
        one = api.evaluate(name, api.Target(strategy=strategy),
                           total_blocks=48)
        _assert_reports_identical(sys_r, one)

    def test_wide_hbm_and_zero_noc_stay_exact(self):
        """An HBM at least as wide as the private DMA and a zero-latency
        NoC must not perturb the 1-cluster numbers either (the delegation
        rule: the arbiter hands through transfer_cycles verbatim)."""
        sys_r = api.evaluate(
            "expf", api.Target.system(1, hbm_bytes_per_cycle=64.0),
            total_blocks=48)
        one = api.evaluate("expf", api.Target(), total_blocks=48)
        _assert_reports_identical(sys_r, one)


class TestSystemScaling:
    def test_compute_bound_strong_scaling_is_exactly_linear(self):
        """poly_lcg moves no bytes: 8 clusters split the same work in
        exactly 1/8 the cycles (divisible block counts, uniform cores)."""
        r1 = api.evaluate("poly_lcg", api.Target.system(1),
                          total_blocks=128)
        r8 = api.evaluate("poly_lcg", api.Target.system(8),
                          total_blocks=128)
        assert r1.cycles_copift == 8 * r8.cycles_copift
        assert r8.power_copift_mw == pytest.approx(8 * r1.power_copift_mw)

    def test_hbm_roofline_flattens_the_curve(self):
        """Behind a 16 B/cycle shared HBM the transfer floor is constant
        in cluster count (water-filling re-slices the same budget), so
        expf stops scaling once it goes memory-bound."""
        cycles = {k: api.evaluate(
            "expf", api.Target.system(k, hbm_bytes_per_cycle=16.0),
            total_blocks=128).cycles_copift for k in (1, 2, 4, 8, 16)}
        assert all(cycles[b] <= cycles[a] for a, b in
                   zip((1, 2, 4, 8), (2, 4, 8, 16)))
        assert cycles[16] == cycles[8]          # flat past the knee
        free = api.evaluate("expf", api.Target.system(16),
                            total_blocks=128).cycles_copift
        assert cycles[16] > free                # the roofline actually bit
        r = api.evaluate("expf",
                         api.Target.system(8, hbm_bytes_per_cycle=16.0),
                         total_blocks=128)
        assert r.dma_bound

    def test_report_totals_span_the_system(self):
        r = api.evaluate("expf", api.Target.system(4), blocks_per_core=2)
        assert r.n_cores == 4 * SNITCH_CLUSTER.n_cores
        assert len(r.core_points) == r.n_cores
        assert len(r.blocks_per_core) == r.n_cores
        assert r.total_blocks == 2 * r.n_cores

    def test_plan_transformed_evaluation_rejected(self):
        with pytest.raises(ValueError, match="single-cluster"):
            api.evaluate("expf", api.Target.system(2), plan=object())

    def test_needs_at_least_one_block(self):
        with pytest.raises(ValueError):
            api.evaluate("expf", api.Target.system(2), total_blocks=0)

    def test_evaluate_system_needs_a_system_config(self):
        with pytest.raises(ValueError, match="no SystemConfig"):
            evaluate_system("expf", api.Target())


class TestTopologyAndGrammar:
    def test_defaults_are_the_lone_cluster(self):
        s = SystemConfig()
        assert s.n_clusters == 1 and s.n_cores == SNITCH_CLUSTER.n_cores
        assert s.is_uniform
        assert s.hbm_bytes_per_cycle is None

    def test_validation(self):
        with pytest.raises(ValueError):
            SystemConfig(clusters=())
        with pytest.raises(TypeError):
            SystemConfig(clusters=("not a cluster",))
        with pytest.raises(ValueError):
            SystemConfig(hbm_bytes_per_cycle=0.0)
        with pytest.raises(ValueError):
            SystemConfig(noc_latency_cycles=-1)
        with pytest.raises(ValueError):
            SystemConfig(cluster_strategy="no_such_strategy")

    def test_parse_system_round_trip(self):
        s = parse_system("4x8c,hbm=256,noc=12,strategy=lpt", SNITCH_CLUSTER)
        assert s.n_clusters == 4
        assert s.clusters[0].n_cores == 8
        assert s.hbm_bytes_per_cycle == 256.0
        assert s.noc_latency_cycles == 12
        assert s.cluster_strategy == "lpt"
        assert parse_system("2x8c,hbm=none",
                            SNITCH_CLUSTER).hbm_bytes_per_cycle is None

    @pytest.mark.parametrize("bad", [
        "", "4", "4x", "x8c", "0x8c", "4x0c", "4x8", "4x8c,hbm",
        "4x8c,hbm=-2", "4x8c,noc=1.5", "4x8c,strategy=nope",
        "4x8c,bogus=1"])
    def test_parse_system_grammar_errors(self, bad):
        with pytest.raises(ValueError):
            parse_system(bad, SNITCH_CLUSTER)


class TestTargetSystem:
    def test_from_int_str_and_config(self):
        by_int = api.Target.system(4, hbm_bytes_per_cycle=256.0)
        by_str = api.Target.system("4x8c,hbm=256")
        by_cfg = api.Target.system(SystemConfig.homogeneous(
            4, SNITCH_CLUSTER, hbm_bytes_per_cycle=256.0))
        assert by_int.system_config == by_str.system_config \
            == by_cfg.system_config
        assert by_int.n_clusters == 4
        assert by_int.n_cores == 32
        assert len(by_int.core_points) == 32

    def test_cluster_must_match_the_system(self):
        sys_cfg = SystemConfig.homogeneous(2, SNITCH_CLUSTER)
        with pytest.raises(ValueError, match="first cluster"):
            api.Target(cluster=SNITCH_CLUSTER.with_cores(4),
                       system_config=sys_cfg)

    def test_exported_from_api(self):
        assert api.SystemConfig is SystemConfig
        assert api.parse_system is parse_system


class TestTunerClusterCount:
    def test_system_point_under_power_cap(self):
        tuner = api.Tuner(api.Target.homogeneous(power_cap_mw=4000.0))
        res = tuner.operating_point("softmax", n_clusters=4)
        assert isinstance(res, SystemPoint)
        assert 1 <= res.n_clusters <= 4
        assert res.feasible
        assert res.best_cost.power_mw <= 4000.0

    def test_time_objective_buys_clusters(self):
        tuner = api.Tuner()
        res = tuner.operating_point("softmax", n_clusters=(1, 2, 4),
                                    objective="time")
        assert res.n_clusters == 4   # more clusters = faster, uncapped

    def test_energy_objective_stays_small(self):
        """Uncapped energy: extra clusters only add power for the same
        work, so the selection keeps the part at one cluster."""
        tuner = api.Tuner()
        res = tuner.operating_point("softmax", n_clusters=(1, 2, 4))
        assert res.n_clusters == 1

    def test_simulatable_kernel_priced_through_evaluate(self):
        est = system_cost("expf", SystemConfig.homogeneous(2,
                                                           SNITCH_CLUSTER),
                          SNITCH_CLUSTER.nominal.name)
        assert est.cycles > 0 and est.power_mw > 0 and est.feasible


class TestServeSystem:
    def test_pricer_partitions_system_cores(self):
        from repro.serve import ServicePricer
        pricer = ServicePricer(system=SystemConfig.homogeneous(
            4, SNITCH_CLUSTER))
        assert pricer.n_cores == 32

    def test_nonuniform_system_rejected(self):
        from repro.serve import ServicePricer
        mixed = SystemConfig(clusters=(SNITCH_CLUSTER,
                                       SNITCH_CLUSTER.with_cores(4)))
        with pytest.raises(ValueError, match="uniform"):
            ServicePricer(system=mixed)

    def test_multi_cluster_slot_prices_via_target_system(self):
        """A slot spanning k whole clusters prices exactly what the
        facade prices on the equivalent Target.system; a sub-cluster
        slot is bit-for-bit the single-cluster pricer."""
        from repro.serve import ServicePricer
        system = SystemConfig.homogeneous(4, SNITCH_CLUSTER)
        pricer = ServicePricer(system=system)
        single = ServicePricer()
        pt = SNITCH_CLUSTER.nominal.name
        est = pricer.price("expf", 65536, 16, pt)
        assert est.cycles == system_cost(
            "expf", SystemConfig.homogeneous(2, SNITCH_CLUSTER), pt,
            problem=65536).cycles
        assert pricer.price("expf", 65536, 4, pt) \
            == single.price("expf", 65536, 4, pt)

    def test_simulate_runs_on_a_system_pricer(self):
        from repro.serve import ServicePricer, StaticPolicy, make_trace, \
            simulate
        pricer = ServicePricer(system=SystemConfig.homogeneous(
            2, SNITCH_CLUSTER))
        tr = make_trace("poisson:rate=400,kernel=softmax,elems=65536",
                        duration_ms=300.0, seed=3)
        rep = simulate(tr, StaticPolicy(rate_rps=tr.mean_rate_rps),
                       pricer=pricer)
        assert rep.n_completed + rep.n_dropped == rep.n_requests


class TestSloAwareAdmission:
    def test_sheds_beat_tail_drop_on_overload(self):
        """Satellite acceptance: on a trace past the plan's capacity the
        SLO-aware gate sheds early and keeps admitted requests within the
        bound — strictly fewer total violations than tail-drop, which
        poisons the queue and lets nearly every completion run late."""
        from repro.serve import (ServicePricer, SloSpec, SlotPlan,
                                 StaticPolicy, make_trace, simulate)
        pricer = ServicePricer()
        plan = SlotPlan(n_slots=1, point="0.50GHz@0.60V", batch_max=1)
        tr = make_trace("poisson:rate=1500,kernel=softmax,elems=65536",
                        duration_ms=1000.0, seed=7)
        slo = SloSpec(latency_ms=5.0)
        tail = simulate(tr, StaticPolicy(plan=plan), slo=slo, pricer=pricer,
                        queue_cap=64)
        shed = simulate(tr, StaticPolicy(plan=plan), slo=slo, pricer=pricer,
                        queue_cap=64, admission="slo_aware")
        assert shed.n_shed > 0
        assert tail.n_shed == 0
        assert shed.slo_violations < tail.slo_violations
        # The gate's point: what it admits, it serves within the bound.
        assert shed.latency_ms["p99"] <= slo.latency_ms
        assert tail.latency_ms["p99"] > slo.latency_ms

    def test_admission_validation(self):
        from repro.serve import SloSpec, StaticPolicy, make_trace, simulate
        tr = make_trace("poisson:rate=50,kernel=softmax,elems=4096",
                        duration_ms=100.0, seed=1)
        with pytest.raises(ValueError, match="admission"):
            simulate(tr, StaticPolicy(rate_rps=50.0),
                     slo=SloSpec(latency_ms=5.0), admission="bogus")
        with pytest.raises(ValueError, match="SloSpec"):
            simulate(tr, StaticPolicy(rate_rps=50.0),
                     admission="slo_aware")


class TestRunHarness:
    def test_structured_rejects_unknown_section_by_name(self):
        from benchmarks.run import _structured
        with pytest.raises(ValueError, match="unknown section 'nope'"):
            _structured("nope")

    def test_structured_known_sections(self):
        from benchmarks.run import _structured
        doc = _structured("system")
        assert doc["acceptance"]["ok"]
        assert _structured("table1") is None   # known, no payload

    def test_system_bench_smoke_contract(self):
        from benchmarks.system_bench import format_lines, generate
        doc = generate(smoke=True)
        assert doc["acceptance"]["ok"]
        effs = doc["scaling_efficiency"]
        assert all(e >= 0.9 for curve in effs.values() for e in curve)
        lines = format_lines(doc)
        assert any(line.startswith("system.acceptance") for line in lines)
