"""Property tests for ``repro.resilience``: FaultTrace determinism (the
whole point of seeded injection — a chaos run is a *replayable* artifact),
the empty trace as the bit-for-bit identity on ``api.evaluate`` across
kernels x strategies, and ``noc.fair_shares`` monotonicity under degraded
HBM widths (a narrower port never makes any stream *faster*).

Property-based cases run when ``hypothesis`` is installed (the CI
configuration); example-based cases pin the same invariants on a bare
install.
"""

import pytest

from repro.api import Target, evaluate
from repro.cluster.scheduler import STRATEGIES
from repro.resilience import FaultTrace, make_faults
from repro.system.noc import fair_shares
from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

KERNELS = ("expf", "montecarlo")
WIDTH_LADDER = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0)

#: Specs covering every event kind plus the stochastic MTTF sampler.
SPECS = (
    "",
    "corefail@2:c0.3",
    "clusterfail@5:c1,throttle@5-20:isl0>0.6GHz",
    "hbm@10-15:0.5x,corefail@1:c0.0",
    "mttf=40ms",
    "mttf=15ms,throttle@2-8:isl0>0.8GHz,hbm@4:0.75x",
)


class TestExamples:
    """Example-based invariants (always run, even without hypothesis)."""

    @pytest.mark.parametrize("spec", SPECS)
    def test_trace_determinism(self, spec):
        """Same (spec, seed, shape) -> identical event tuple; a different
        seed changes only the sampled (mttf) part."""
        kw = dict(duration_ms=100.0, n_clusters=2, cores_per_cluster=4)
        a = make_faults(spec, seed=7, **kw)
        b = make_faults(spec, seed=7, **kw)
        assert a == b
        assert a.events == b.events
        if "mttf" in spec:
            c = make_faults(spec, seed=8, **kw)
            assert c.events != a.events

    @pytest.mark.parametrize("strategy", STRATEGIES)
    @pytest.mark.parametrize("kernel", KERNELS)
    def test_empty_trace_is_identity(self, kernel, strategy):
        """``faults=FaultTrace.empty()`` must reproduce the fault-free
        Report bit-for-bit — by construction (the trivial state routes
        down the historical code path), pinned here per kernel x
        strategy."""
        target = Target(strategy=strategy)
        base = evaluate(kernel, target, total_blocks=13)
        empty = evaluate(kernel, target, total_blocks=13,
                         faults=FaultTrace.empty())
        parsed = evaluate(kernel, target, total_blocks=13,
                          faults=make_faults(""))
        assert empty == base
        assert parsed == base

    @pytest.mark.parametrize("widths", [
        (64.0,), (8.0, 8.0), (4.0, 16.0, 64.0), (1.0, 1.0, 32.0, 32.0)])
    def test_fair_shares_monotone_in_port(self, widths):
        healthy = fair_shares(widths, 64.0)
        for scale in (0.75, 0.5, 0.25, 0.1):
            degraded = fair_shares(widths, 64.0 * scale)
            assert all(d <= h + 1e-12
                       for d, h in zip(degraded, healthy))
            assert sum(degraded) <= min(64.0 * scale, sum(widths)) + 1e-9

    def test_fair_shares_never_exceed_width(self):
        shares = fair_shares((4.0, 16.0, 64.0), 32.0)
        assert all(s <= w for s, w in zip(shares, (4.0, 16.0, 64.0)))


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestProperties:
    """Property-based generalizations of the same invariants."""

    @given(seed=st.integers(min_value=0, max_value=2**31 - 1),
           mttf=st.floats(min_value=5.0, max_value=200.0),
           n_clusters=st.integers(min_value=1, max_value=4),
           cores=st.integers(min_value=1, max_value=8))
    @settings(max_examples=40, deadline=None)
    def test_mttf_trace_replays(self, seed, mttf, n_clusters, cores):
        """The stochastic sampler is a pure function of (spec, seed,
        shape): replaying yields the identical event tuple, every victim
        is in-shape, and events arrive time-sorted."""
        kw = dict(duration_ms=200.0, n_clusters=n_clusters,
                  cores_per_cluster=cores)
        a = make_faults(f"mttf={mttf}ms", seed=seed, **kw)
        b = make_faults(f"mttf={mttf}ms", seed=seed, **kw)
        assert a.events == b.events
        assert all(e.cluster < n_clusters and e.core < cores
                   for e in a.events)
        times = [e.t_ms for e in a.events]
        assert times == sorted(times)

    @given(strategy=st.sampled_from(STRATEGIES),
           blocks=st.integers(min_value=0, max_value=64))
    @settings(max_examples=20, deadline=None)
    def test_empty_trace_identity_over_blocks(self, strategy, blocks):
        target = Target(strategy=strategy)
        base = evaluate("expf", target, total_blocks=blocks)
        empty = evaluate("expf", target, total_blocks=blocks,
                         faults=FaultTrace.empty())
        assert empty == base

    @given(widths=st.lists(st.sampled_from(WIDTH_LADDER),
                           min_size=1, max_size=8),
           port=st.floats(min_value=0.5, max_value=256.0),
           scale=st.floats(min_value=0.01, max_value=1.0))
    @settings(max_examples=80, deadline=None)
    def test_fair_shares_monotone_under_degradation(self, widths, port,
                                                    scale):
        """An HBM window that narrows the port (``hbm@...:<scale>x``)
        can only shrink every stream's effective bytes/cycle."""
        widths = tuple(widths)
        healthy = fair_shares(widths, port)
        degraded = fair_shares(widths, port * scale)
        assert all(d <= h + 1e-9 for d, h in zip(degraded, healthy))
        assert all(0.0 <= s <= w + 1e-9
                   for s, w in zip(degraded, widths))
