"""Training-substrate tests: optimizer, checkpointing, data determinism,
gradient compression, straggler detection."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import load_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.parallel.compress import ef_compress, ef_init, quantize_dequantize
from repro.train import checkpoint as ckpt
from repro.train.fault import CheckpointManager, StragglerMonitor
from repro.train.optimizer import (AdamWConfig, adamw_update,
                                   clip_by_global_norm, global_norm,
                                   init_opt_state, lr_at)


class TestAdamW:
    def _quad_setup(self):
        params = {"w": jnp.asarray([3.0, -2.0, 1.0]),
                  "b": jnp.asarray([0.5])}
        def loss(p):
            return jnp.sum(jnp.square(p["w"])) + jnp.sum(jnp.square(p["b"]))
        return params, loss

    def test_converges_on_quadratic(self):
        params, loss = self._quad_setup()
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=200, grad_clip=1e9)
        state = init_opt_state(params)
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(cfg, params, g, state)
        assert float(loss(params)) < 1e-3

    def test_weight_decay_applies_to_matrices_only(self):
        params = {"ffn": {"up": {"w": jnp.ones((4, 4))}},
                  "norm": {"g": jnp.ones((4,))}}
        grads = jax.tree.map(jnp.zeros_like, params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=0)
        state = init_opt_state(params)
        new, _, _ = adamw_update(cfg, params, grads, state)
        assert float(jnp.max(jnp.abs(new["ffn"]["up"]["w"] - 1.0))) > 1e-5
        np.testing.assert_allclose(np.asarray(new["norm"]["g"]), 1.0)

    def test_lr_schedule(self):
        cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
        assert float(lr_at(cfg, jnp.asarray(5))) == pytest.approx(5e-4)
        assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1e-3, rel=0.01)
        assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(1e-4, rel=0.01)

    def test_grad_clipping(self):
        grads = {"w": jnp.full((10,), 100.0)}
        clipped, gn = clip_by_global_norm(grads, 1.0)
        assert float(gn) == pytest.approx(100.0 * np.sqrt(10), rel=1e-5)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_bf16_state_dtype(self):
        params = {"w": jnp.ones((4,), jnp.float32)}
        state = init_opt_state(params, "bfloat16")
        assert state["m"]["w"].dtype == jnp.bfloat16


class TestCheckpoint:
    def test_roundtrip_bitexact(self, tmp_path):
        tree = {"a": jnp.arange(7, dtype=jnp.float32),
                "b": {"c": jnp.ones((3, 2), jnp.bfloat16),
                      "d": jnp.asarray(5, jnp.int32)}}
        path = str(tmp_path / "x.msgpack")
        ckpt.save(path, tree, {"step": 3})
        restored, meta = ckpt.load(path, like=jax.eval_shape(lambda: tree))
        assert meta["step"] == 3
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_atomic_no_partial_files(self, tmp_path):
        path = str(tmp_path / "y.msgpack")
        ckpt.save(path, {"a": jnp.zeros(4)})
        assert not os.path.exists(path + ".tmp")

    def test_manager_retention_and_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"v": jnp.asarray(s)})
        assert mgr.latest() == 4
        assert mgr.all_steps() == [3, 4]

    def test_restore_or_init(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        like = jax.eval_shape(lambda: {"v": jnp.zeros(3)})
        state, step = mgr.restore_or_init(like, lambda: {"v": jnp.ones(3)})
        assert step == 0 and float(state["v"][0]) == 1.0
        mgr.save(7, {"v": jnp.full((3,), 7.0)})
        state, step = mgr.restore_or_init(like, lambda: {"v": jnp.ones(3)})
        assert step == 7 and float(state["v"][0]) == 7.0

    def test_async_saver(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(1, {"v": jnp.arange(1000.0)})
        mgr.wait()
        assert mgr.latest() == 1


class TestDataPipeline:
    def _pipe(self, seed=1):
        cfg = load_config("olmo-1b", "smoke")
        shape = ShapeConfig("t", 64, 4, "train")
        return TokenPipeline(cfg, shape, PipelineConfig(seed=seed))

    def test_deterministic_across_instances(self):
        a = self._pipe().global_batch_at(5)
        b = self._pipe().global_batch_at(5)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))

    def test_steps_differ(self):
        p = self._pipe()
        a, b = p.global_batch_at(1), p.global_batch_at(2)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))

    def test_tokens_in_vocab(self):
        t = np.asarray(self._pipe().global_batch_at(0)["tokens"])
        assert t.min() >= 0 and t.max() < 503

    def test_sticky_structure_learnable(self):
        """~90% of consecutive tokens repeat → the stream has structure."""
        t = np.asarray(self._pipe().global_batch_at(0)["tokens"])
        frac_repeat = (t[:, 1:] == t[:, :-1]).mean()
        assert 0.8 < frac_repeat < 0.95

    def test_host_slice_is_view_of_global(self):
        p = self._pipe()
        g = p.global_batch_at(3)
        h = p.host_batch_at(3)
        np.testing.assert_array_equal(np.asarray(h["tokens"]),
                                      np.asarray(g["tokens"]))  # 1 host


class TestCompression:
    def test_quantize_bounded_error(self):
        g = jnp.asarray(np.random.default_rng(0).normal(0, 1, (1000,)),
                        jnp.float32)
        g_hat, resid = quantize_dequantize(g)
        assert float(jnp.max(jnp.abs(resid))) <= float(jnp.max(jnp.abs(g))) / 127
        np.testing.assert_allclose(np.asarray(g_hat + resid), np.asarray(g),
                                   rtol=1e-6)

    def test_error_feedback_unbiased_over_time(self):
        """EF: the accumulated transmitted signal tracks the true sum."""
        rng = np.random.default_rng(1)
        grads = {"w": jnp.asarray(rng.normal(0, 1, (100,)), jnp.float32)}
        e = ef_init(grads)
        sent = jnp.zeros(100)
        total = jnp.zeros(100)
        for i in range(50):
            g = {"w": jnp.asarray(rng.normal(0, 1, (100,)), jnp.float32)}
            total = total + g["w"]
            g_hat, e = ef_compress(g, e)
            sent = sent + g_hat["w"]
        # Residual is bounded (one quantization step), not growing.
        np.testing.assert_allclose(np.asarray(sent), np.asarray(total),
                                   atol=0.1)


class TestStraggler:
    def test_detects_slow_host(self):
        mon = StragglerMonitor()
        flagged = []
        for step in range(20):
            for host in ("h0", "h1", "h2", "h3"):
                dt = 1.0 + (0.02 * step % 0.05)
                if host == "h3" and step > 10:
                    dt = 3.0
                if mon.record(host, step, dt):
                    flagged.append((host, step))
        hosts = {h for h, _ in flagged}
        assert hosts == {"h3"}

    def test_rebalance_moves_work(self):
        mon = StragglerMonitor()
        for step in range(12):
            mon.record("h0", step, 1.0)
            mon.record("h1", step, 1.02)
            mon.record("h2", step, 4.0 if step > 8 else 1.0)
        plan = mon.rebalance_plan({"h0": 4, "h1": 4, "h2": 4})
        assert plan["h2"] < 4 and sum(plan.values()) == 12

    def test_no_false_positives_on_uniform(self):
        mon = StragglerMonitor()
        rng = np.random.default_rng(0)
        for step in range(30):
            for host in ("a", "b"):
                mon.record(host, step, 1.0 + 0.01 * rng.random())
        assert not mon.events

    def test_detections_land_in_obs_metrics(self):
        from repro import obs
        mon = StragglerMonitor()
        with obs.session(trace=False, metrics=True) as s:
            for step in range(16):
                for host in ("h0", "h1", "h2", "h3"):
                    dt = 5.0 if host == "h3" and step > 10 else 1.0
                    mon.record(host, step, dt)
        m = s.metrics()
        assert m["train.straggler.detected"]["value"] == len(mon.events) > 0
        assert m["train.straggler.step_seconds.h3"]["value"] == 5.0
        assert m["train.straggler.step_seconds.h0"]["value"] == 1.0
        assert m["train.straggler.last_z.h3"]["value"] > 3.5

    def test_metrics_disabled_is_no_op(self):
        from repro.obs import metrics as obs_metrics
        before = obs_metrics.REGISTRY.snapshot()
        mon = StragglerMonitor()
        for step in range(16):
            for host in ("h0", "h1", "h2", "h3"):
                dt = 5.0 if host == "h3" and step > 10 else 1.0
                mon.record(host, step, dt)
        assert mon.events                      # detection still works...
        assert obs_metrics.REGISTRY.snapshot() == before  # ...silently
