"""Dual-issue timing + energy models vs the paper's measured results.

The microarchitectural constants in isa.py/timing.py/energy.py were
calibrated ONCE against the aggregates the paper publishes; these tests pin
the calibration so regressions in the simulator surface immediately.
Tolerances: ±5 % per-kernel, ±4–6 % on aggregates (the paper itself reads
some of these off bar charts).
"""

import pytest

from repro.core.analytics import PAPER_HEADLINE, TABLE_I, geomean
from repro.core.energy import copift_power, baseline_power, evaluate_energy
from repro.core.isa import Instr
from repro.core.kernels_isa import KERNELS, baseline_trace, copift_schedule
from repro.core.timing import (CopiftSchedule, copift_block_timing,
                               copift_problem_timing, evaluate_kernel,
                               ipc_surface, simulate_single_issue,
                               thread_cycles)
from repro.perf import memo
from tests._hypothesis_compat import given, settings, st


@pytest.fixture(scope="module")
def results():
    return {k: evaluate_kernel(k, baseline_trace(k), copift_schedule(k),
                               TABLE_I[k].max_block) for k in KERNELS}


class TestPerformance:
    def test_geomean_speedup(self, results):
        """Paper abstract: 1.47× average speedup over RV32G baselines."""
        g = geomean([r.speedup for r in results.values()])
        assert g == pytest.approx(PAPER_HEADLINE["geomean_speedup"], rel=0.04)

    def test_peak_speedup_is_expf(self, results):
        """Paper §III-A: peak 2.05× on the exp kernel."""
        best = max(results.values(), key=lambda r: r.speedup)
        assert best.name == "expf"
        assert best.speedup == pytest.approx(PAPER_HEADLINE["peak_speedup"],
                                             rel=0.05)

    def test_peak_ipc(self, results):
        """Paper abstract: peak IPC of 1.75 — clear dual-issue evidence."""
        peak = max(r.ipc_copift for r in results.values())
        assert peak == pytest.approx(PAPER_HEADLINE["peak_ipc"], rel=0.05)
        assert peak > 1.0   # the whole point: >1 on an in-order core

    def test_geomean_ipc_gain(self, results):
        """Paper §III-A: geomean IPC improvement 1.62×."""
        g = geomean([r.ipc_gain for r in results.values()])
        assert g == pytest.approx(PAPER_HEADLINE["geomean_ipc_gain"], rel=0.04)

    def test_poly_lcg_near_ideal_gain(self, results):
        """Paper §III-A: LCG writeback-port stalls balance the threads in
        poly_lcg → near-ideal IPC improvement (1.97×, i.e. ≈2)."""
        assert results["poly_lcg"].ipc_gain == pytest.approx(1.97, rel=0.05)

    def test_pi_lcg_below_expectation(self, results):
        """...while the same stalls unbalance pi_lcg (gain < I' = 1.78)."""
        assert results["pi_lcg"].ipc_gain < TABLE_I["pi_lcg"].i_prime - 0.05

    def test_ipc_correlates_with_i_prime(self, results):
        """Fig. 2a: measured IPC gain tracks I' (within the LCG deviations)."""
        for name in ("expf", "logf", "poly_xoshiro128p", "pi_xoshiro128p"):
            assert results[name].ipc_gain == pytest.approx(
                TABLE_I[name].i_prime, rel=0.10)

    def test_baseline_ipc_below_one(self, results):
        for r in results.values():
            assert r.ipc_base <= 1.0

    def test_speedup_exceeds_two_via_ldst_elision(self, results):
        """Paper §III-A: 'speedups greater than two are possible, as a result
        of additional optimizations, such as load-store elision with the
        SSRs, on top of dual-issue execution.'"""
        assert results["expf"].speedup > 2.0


class TestBlockSizeSweep:
    """Fig. 3 — IPC vs problem size and block size (poly_lcg)."""

    def test_ipc_increases_with_problem_size(self):
        sched = copift_schedule("poly_lcg")
        ipcs = [copift_problem_timing(sched, n, 64).ipc
                for n in (64, 256, 1024, 4096)]
        assert all(b >= a - 1e-9 for a, b in zip(ipcs, ipcs[1:]))

    def test_small_blocks_amortize_sooner(self):
        """Smaller blocks reach their (lower) peak at smaller problem sizes."""
        sched = copift_schedule("poly_lcg")
        def frac_of_max(block):
            peak = copift_problem_timing(sched, 1 << 16, block).ipc
            return copift_problem_timing(sched, 1024, block).ipc / peak
        assert frac_of_max(32) > frac_of_max(256)

    def test_larger_blocks_higher_steady_ipc(self):
        sched = copift_schedule("poly_lcg")
        steady32 = copift_block_timing(sched, 32).ipc
        steady341 = copift_block_timing(sched, TABLE_I["poly_lcg"].max_block).ipc
        assert steady341 > steady32

    def test_surface_shape(self):
        sched = copift_schedule("poly_lcg")
        surf = ipc_surface(sched, [256, 4096], [32, 341])
        # b > n cells are skipped (341 > 256).
        assert set(surf) == {(256, 32), (4096, 32), (4096, 341)}
        assert all(0 < v < 2.0 for v in surf.values())

    def test_converges_to_steady_state(self):
        """Fig. 3: 'as we tend to amortize all overheads, the IPC converges
        to the steady-state IPC presented in Fig. 2a.'"""
        sched = copift_schedule("poly_lcg")
        block = TABLE_I["poly_lcg"].max_block
        big = copift_problem_timing(sched, 1 << 18, block).ipc
        steady = copift_block_timing(sched, block).ipc
        assert big == pytest.approx(steady, rel=0.02)


class TestEnergy:
    @pytest.fixture(scope="class")
    def energies(self):
        return [evaluate_energy(k) for k in KERNELS]

    def test_geomean_power_ratio(self, energies):
        """Paper §III-B: geomean power increase only 1.07×."""
        g = geomean([e.power_ratio for e in energies])
        assert g == pytest.approx(PAPER_HEADLINE["geomean_power_ratio"],
                                  abs=0.04)

    def test_max_power_ratio(self, energies):
        """Paper §III-B: maximum power increase 1.17×."""
        m = max(e.power_ratio for e in energies)
        assert m == pytest.approx(PAPER_HEADLINE["max_power_ratio"], abs=0.05)

    def test_geomean_energy_saving(self, energies):
        """Paper abstract: 1.37× average energy savings."""
        g = geomean([e.energy_saving for e in energies])
        assert g == pytest.approx(PAPER_HEADLINE["geomean_energy_saving"],
                                  abs=0.06)

    def test_peak_energy_saving_is_expf(self, energies):
        """Paper §III-B: peak 1.93× saving on the exp kernel."""
        best = max(energies, key=lambda e: e.energy_saving)
        assert best.name == "expf"
        assert best.energy_saving == pytest.approx(
            PAPER_HEADLINE["peak_energy_saving"], rel=0.05)

    def test_monte_carlo_lower_base_power(self, energies):
        """Paper §III-B: MC baselines sit below exp/log (DMA idle, fewer L1
        accesses)."""
        by_name = {e.name: e for e in energies}
        mc = max(by_name[k].power_base_mw for k in KERNELS if "lcg" in k
                 or "xoshiro" in k)
        stream = min(by_name[k].power_base_mw for k in ("expf", "logf"))
        assert mc < stream

    def test_icache_win_for_exp_log(self):
        """Paper §III-B: exp/log COPIFT integer bodies (<64 instrs) fit the
        L0 I$ → fetch power drops vs the thrashing baseline."""
        for name in ("expf", "logf"):
            b = baseline_power(name)
            c = copift_power(name)
            # fetch component per issued instruction must drop
            assert c.fetch < b.fetch

    def test_energy_saving_positive_everywhere(self, energies):
        for e in energies:
            assert e.energy_saving > 1.0


# ---------------------------------------------------------------------------
# repro.perf timing memo — transparency (identical cycles, hot or cold)
# ---------------------------------------------------------------------------

def _random_body(spec: "list[tuple[int, int, int]]") -> list[Instr]:
    """Deterministically expand a drawn spec into a well-formed mixed
    int/FP/mem instruction body (register names follow the RISC-V
    convention the simulator keys on: ``f*`` = FP RF)."""
    ops = ("add", "xor", "mul", "srli", "lw", "sw",
           "fadd.d", "fmul.d", "fmadd.d")
    body: list[Instr] = []
    for sel, a, b in spec:
        op = ops[sel % len(ops)]
        if op == "lw":
            body.append(Instr("lw", f"r{a % 6}",
                              (f"loop:p{b % 3}", f"mem:m{b % 3}")))
        elif op == "sw":
            body.append(Instr("sw", f"mem:m{b % 3}", (f"r{a % 6}",)))
        elif op.startswith("f"):
            body.append(Instr(op, f"f{a % 6}", (f"f{b % 6}", "const:c")))
        else:
            body.append(Instr(op, f"r{a % 6}", (f"r{b % 6}",)))
    return body


class TestTimingMemoTransparency:
    """The repro.perf memo must never change a number: warm (memoized,
    including cache hits) and cold (memo bypassed) runs agree exactly."""

    @settings(max_examples=30, deadline=None)
    @given(spec=st.lists(st.tuples(st.integers(0, 8), st.integers(0, 5),
                                   st.integers(0, 5)),
                         min_size=1, max_size=14),
           iters=st.integers(1, 24),
           block=st.sampled_from((1, 2, 7, 8, 16, 33)),
           contention=st.sampled_from((0.0, 0.25, 0.4375)))
    def test_property_memo_equals_cold(self, spec, iters, block, contention):
        body = _random_body(spec)
        fp_body = [Instr("fmadd.d", "facc", ("facc", "loop:ssr0",
                                             "const:c"))] + \
            [i for i in body if i.opcode.startswith("f")][:4]
        sched = CopiftSchedule("prop", int_body=list(body),
                               fp_bodies=[fp_body])
        with memo.memo_disabled():
            cold = (simulate_single_issue(body, iters),
                    thread_cycles(body, iters, contention),
                    copift_block_timing(sched, block, contention),
                    copift_problem_timing(sched, 8 * block, block))
        memo.clear_all()
        # First warm pass populates the tables, second one hits them;
        # both must reproduce the cold numbers exactly.
        for _ in range(2):
            warm = (simulate_single_issue(body, iters),
                    thread_cycles(body, iters, contention),
                    copift_block_timing(CopiftSchedule(
                        "prop", int_body=list(body),
                        fp_bodies=[list(fp_body)]), block, contention),
                    copift_problem_timing(sched, 8 * block, block))
            assert warm == cold

    @pytest.mark.parametrize("name", ("expf", "pi_lcg"))
    def test_registry_kernels_memo_equals_cold(self, name):
        block = TABLE_I[name].max_block
        with memo.memo_disabled():
            cold = evaluate_kernel(name, baseline_trace(name),
                                   copift_schedule(name), block)
        memo.clear_all()
        warm = evaluate_kernel(name, baseline_trace(name),
                               copift_schedule(name), block)
        hit = evaluate_kernel(name, baseline_trace(name),
                              copift_schedule(name), block)
        assert warm == cold == hit

    def test_ipc_surface_values_unchanged(self):
        """Regression for the per-schedule cache rewiring: every grid cell
        equals the cold-path value exactly (and the b > n skip rule is
        preserved)."""
        problems, blocks = [256, 1024, 4096], [32, 64, 341]
        with memo.memo_disabled():
            cold = ipc_surface(copift_schedule("poly_lcg"), problems, blocks)
        memo.clear_all()
        warm = ipc_surface(copift_schedule("poly_lcg"), problems, blocks)
        assert set(warm) == set(cold)
        assert warm == cold

    def test_memo_disabled_context_restores(self):
        assert memo.enabled()
        with memo.memo_disabled():
            assert not memo.enabled()
        assert memo.enabled()
