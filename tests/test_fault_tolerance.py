"""Fault-tolerance integration tests: kill a real training run mid-flight,
restart, and verify the continuation — plus elastic re-shard onto a
different device mesh (subprocess with a different host-device count)."""

import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _run_train(args, env=None, **kw):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        cwd=REPO, env=env or ENV, capture_output=True, text=True,
        timeout=420, **kw)


@pytest.mark.slow
class TestCrashResume:
    def test_kill_and_resume_reaches_completion(self, tmp_path):
        ckpt_dir = str(tmp_path / "ck")
        metrics = str(tmp_path / "m.json")
        # 300 steps ≈ 15-20 s of post-compile run time: the kill reliably
        # lands mid-run (a 30-step run can finish inside one poll interval).
        args = ["--arch", "olmo-1b", "--variant", "smoke", "--steps", "300",
                "--batch", "4", "--seq", "64", "--ckpt-dir", ckpt_dir,
                "--ckpt-every", "20", "--metrics-out", metrics]
        # Start, then kill mid-run (SIGKILL — a real crash).
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.train"] + args,
            cwd=REPO, env=ENV, stdout=subprocess.PIPE, text=True)
        deadline = time.time() + 300
        killed = False
        while time.time() < deadline:
            if any(f.startswith("step_") for f in
                   (os.listdir(ckpt_dir) if os.path.isdir(ckpt_dir) else [])):
                time.sleep(1.0)
                proc.kill()
                killed = True
                break
            if proc.poll() is not None:
                break
            time.sleep(0.5)
        proc.wait()
        assert killed, "run finished before a checkpoint appeared"

        # Restart: must resume from the checkpoint, not step 0.
        r = _run_train(args)
        assert r.returncode == 0, r.stderr
        assert "[resume] from step" in r.stdout
        hist = json.load(open(metrics))
        assert hist, "resumed run recorded no steps (kill landed at the end?)"
        assert hist[-1]["step"] == 299
        assert hist[0]["step"] > 0

    def test_resumed_batches_identical(self, tmp_path):
        """Determinism contract: a resumed run consumes the same data as an
        uninterrupted one (pipeline is (seed, step)-keyed)."""
        m1 = str(tmp_path / "a.json")
        m2 = str(tmp_path / "b.json")
        base = ["--arch", "olmo-1b", "--variant", "smoke", "--batch", "4",
                "--seq", "64"]
        r = _run_train(base + ["--steps", "12", "--metrics-out", m1,
                               "--ckpt-dir", str(tmp_path / "c1"),
                               "--ckpt-every", "6"])
        assert r.returncode == 0, r.stderr
        # Second run: stop at 6 (checkpoint), then continue to 12.
        r = _run_train(base + ["--steps", "6",
                               "--ckpt-dir", str(tmp_path / "c2"),
                               "--ckpt-every", "6"])
        assert r.returncode == 0, r.stderr
        r = _run_train(base + ["--steps", "12", "--metrics-out", m2,
                               "--ckpt-dir", str(tmp_path / "c2"),
                               "--ckpt-every", "6"])
        assert r.returncode == 0, r.stderr
        h1 = {d["step"]: d["loss"] for d in json.load(open(m1))}
        h2 = {d["step"]: d["loss"] for d in json.load(open(m2))}
        # Cross-process tolerance: XLA:CPU re-compiles may change reduction
        # splits (~1e-3 relative); in-process determinism is pinned exactly
        # by tests/test_system.py::test_training_is_deterministic.
        for s in range(6, 12):
            assert h1[s] == pytest.approx(h2[s], rel=2e-2), s


@pytest.mark.slow
class TestElastic:
    def test_restore_onto_different_mesh(self, tmp_path):
        """Save under an 8-device mesh, restore under 4 — the elastic
        shrink after losing hosts.  Runs in subprocesses because the
        host-device count is locked at jax init."""
        script = r"""
import os, sys
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import jax, jax.numpy as jnp
from repro.configs import load_config
from repro.models.model import init_params
from repro.parallel.sharding import ShardingRules
from repro.launch.mesh import make_mesh
from repro.train.fault import CheckpointManager, elastic_restore

n = int(sys.argv[1]); mode = sys.argv[2]; path = sys.argv[3]
cfg = load_config("olmo-1b", "smoke")
mesh = make_mesh((n // 2, 2), ("data", "model"))
rules = ShardingRules(cfg, mesh)
mgr = CheckpointManager(path, async_save=False)
like = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(7)))
if mode == "save":
    params = init_params(cfg, jax.random.PRNGKey(7))
    mgr.save(11, params)
    print("SAVED", float(jax.tree.leaves(params)[0].astype(jnp.float32).sum()))
else:
    params, step = elastic_restore(mgr, like, mesh,
                                   lambda l: rules.params_shardings(l))
    leaf = jax.tree.leaves(params)[0]
    assert step == 11
    assert len(leaf.sharding.device_set) >= 1
    print("RESTORED", float(leaf.astype(jnp.float32).sum()))
"""
        path = str(tmp_path / "elastic")
        r1 = subprocess.run([sys.executable, "-c", script, "8", "save", path],
                            cwd=REPO, env=ENV, capture_output=True, text=True,
                            timeout=240)
        assert r1.returncode == 0, r1.stderr
        r2 = subprocess.run([sys.executable, "-c", script, "4", "load", path],
                            cwd=REPO, env=ENV, capture_output=True, text=True,
                            timeout=240)
        assert r2.returncode == 0, r2.stderr
        v1 = float(r1.stdout.split()[-1])
        v2 = float(r2.stdout.split()[-1])
        assert v1 == pytest.approx(v2, rel=1e-6)
