"""Heterogeneous-cluster invariants.

THE contract (the homogeneous-reduction invariant): a heterogeneous
cluster whose cores all sit at identical operating points must reproduce
the homogeneous machinery's numbers *bit-for-bit* — the single-core and
homogeneous-cluster figures pinned by ``tests/test_cluster.py`` stay the
ground truth, and the island path is a strict extension.  Plus: the
weighted schedules actually help on mixed islands, and the tuner's
heterogeneous operating point never scores worse than the homogeneous one
under the same power cap.
"""

import pytest

from repro import api
from repro.cluster import (NOMINAL_POINT, SNITCH_CLUSTER, ClusterConfig,
                           DvfsIsland, compare_strategies,
                           het_cluster_power_mw, cluster_power_mw,
                           parse_islands)
from repro.cluster.scheduler import STRATEGIES
from repro.core.analytics import TABLE_I
from repro.core.energy import evaluate_energy
from repro.core.kernels_isa import KERNELS, baseline_trace, copift_schedule
from repro.core.timing import evaluate_kernel

BIG = SNITCH_CLUSTER.point("1.45GHz@1.00V")
LITTLE = SNITCH_CLUSTER.point("0.50GHz@0.60V")
BIG_LITTLE = SNITCH_CLUSTER.with_islands(DvfsIsland(2, BIG),
                                         DvfsIsland(6, LITTLE))


def _hom(name, n_cores=8, point=NOMINAL_POINT):
    """The old homogeneous evaluate_cluster call, via the facade."""
    return api.evaluate(name, api.Target.homogeneous(n_cores=n_cores,
                                                     point=point))


def _het(name, cfg, strategy="lpt", total_blocks=None):
    """The old evaluate_cluster_het call, via the facade."""
    return api.evaluate(name, api.Target(cluster=cfg, strategy=strategy),
                        total_blocks=total_blocks)


class TestTopology:
    def test_islands_must_cover_the_cores(self):
        with pytest.raises(ValueError):
            ClusterConfig(n_cores=8, islands=(DvfsIsland(2, BIG),))

    def test_island_needs_cores(self):
        with pytest.raises(ValueError):
            DvfsIsland(0, BIG)

    def test_with_islands_sets_core_count(self):
        assert BIG_LITTLE.n_cores == 8
        assert BIG_LITTLE.is_heterogeneous
        assert BIG_LITTLE.core_points() == (BIG,) * 2 + (LITTLE,) * 6

    def test_with_cores_drops_stale_islands(self):
        assert BIG_LITTLE.with_cores(4).islands is None

    def test_homogeneous_core_points_use_default(self):
        assert SNITCH_CLUSTER.core_points(BIG) == (BIG,) * 8
        assert SNITCH_CLUSTER.core_points() == (NOMINAL_POINT,) * 8

    def test_uniform_islands_not_heterogeneous(self):
        cfg = SNITCH_CLUSTER.with_islands(DvfsIsland(4, BIG),
                                          DvfsIsland(4, BIG))
        assert not cfg.is_heterogeneous

    def test_parse_islands_round_trip(self):
        isl = parse_islands("2@1.45GHz@1.00V,6@0.50GHz@0.60V",
                            SNITCH_CLUSTER)
        assert isl == (DvfsIsland(2, BIG), DvfsIsland(6, LITTLE))
        with pytest.raises(ValueError):
            parse_islands("x@1.45GHz@1.00V", SNITCH_CLUSTER)
        with pytest.raises(ValueError):
            parse_islands("2@3.00GHz@9.00V", SNITCH_CLUSTER)


class TestHomogeneousReduction:
    """Identical per-core points → the island path reproduces the
    homogeneous numbers bit-for-bit, for every strategy."""

    @pytest.mark.parametrize("strategy", STRATEGIES)
    @pytest.mark.parametrize("name", KERNELS)
    def test_cluster_8core_nominal_exact(self, name, strategy):
        hom = _hom(name)
        het = _het(name, SNITCH_CLUSTER, strategy)
        assert het.cycles_copift == hom.cycles_copift
        assert het.cycles_base == hom.cycles_base
        assert het.speedup == hom.speedup
        assert het.ipc_copift == hom.ipc_copift
        assert het.ipc_base == hom.ipc_base
        assert het.power_copift_mw == hom.power_copift_mw
        assert het.power_base_mw == hom.power_base_mw
        assert het.energy_saving == hom.energy_saving
        assert het.time_us == hom.time_us
        assert het.energy_pj_per_elem == hom.energy_pj_per_elem
        assert het.dma_bound == hom.dma_bound
        assert het.dma_utilization == hom.dma_utilization

    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_single_core_reduces_to_paper_numbers(self, strategy):
        """One core at nominal through the heterogeneous path equals the
        paper-calibrated single-PE machinery — the same contract
        ``tests/test_cluster.py`` pins for the homogeneous path."""
        cfg1 = SNITCH_CLUSTER.with_cores(1)
        for name in KERNELS:
            pe = evaluate_kernel(name, baseline_trace(name),
                                 copift_schedule(name),
                                 TABLE_I[name].max_block)
            het = _het(name, cfg1, strategy)
            assert het.speedup == pe.speedup
            assert het.ipc_copift == pe.ipc_copift
            assert het.cycles_copift == pe.cycles_copift
            assert het.cycles_base == pe.cycles_base
            en = evaluate_energy(name)
            assert het.energy_saving == en.energy_saving
            assert het.power_ratio == en.power_ratio

    def test_explicit_uniform_islands_also_exact(self):
        cfg = SNITCH_CLUSTER.with_islands(DvfsIsland(3, NOMINAL_POINT),
                                          DvfsIsland(5, NOMINAL_POINT))
        hom = _hom("expf")
        het = _het("expf", cfg, "lpt")
        assert het.cycles_copift == hom.cycles_copift
        assert het.energy_pj_per_elem == hom.energy_pj_per_elem

    def test_het_power_grouping_matches_homogeneous_product(self):
        for n in (1, 3, 8):
            assert het_cluster_power_mw(SNITCH_CLUSTER, "expf",
                                        (NOMINAL_POINT,) * n) \
                == cluster_power_mw(SNITCH_CLUSTER, "expf", n)


class TestHeterogeneousBehavior:
    def test_weighted_strategies_beat_block_cyclic_on_big_little(self):
        res = compare_strategies("expf", BIG_LITTLE, total_blocks=48)
        assert res["lpt"].time_us < res["block_cyclic"].time_us
        assert res["static_proportional"].time_us \
            < res["block_cyclic"].time_us
        assert res["lpt"].imbalance < res["block_cyclic"].imbalance

    def test_big_cores_get_more_blocks(self):
        r = _het("expf", BIG_LITTLE, "lpt", total_blocks=48)
        big_share = min(r.blocks_per_core[:2])
        little_share = max(r.blocks_per_core[2:])
        assert big_share > little_share

    def test_reference_clock_is_the_fastest_island(self):
        r = _het("expf", BIG_LITTLE, "lpt")
        assert r.ref_freq_ghz == BIG.freq_ghz

    def test_mixed_islands_power_between_extremes(self):
        r = _het("expf", BIG_LITTLE, "lpt")
        all_big = _hom("expf", point=BIG)
        all_little = _hom("expf", point=LITTLE)
        assert all_little.power_copift_mw < r.power_copift_mw \
            < all_big.power_copift_mw

    def test_needs_at_least_one_block(self):
        with pytest.raises(ValueError):
            _het("expf", BIG_LITTLE, total_blocks=0)


class TestHeterogeneousTuner:
    def test_uniform_island_candidate_prices_like_homogeneous(self):
        from repro.tune import Candidate, evaluate, get_workload
        w = get_workload("expf")
        for pt in SNITCH_CLUSTER.operating_points:
            hom = evaluate(w, Candidate(block=w.max_block, n_cores=8,
                                        point=pt.name))
            het = evaluate(w, Candidate(block=w.max_block, n_cores=8,
                                        islands=(pt.name,), strategy="lpt"))
            assert het.cycles == hom.cycles
            assert het.time_ns == hom.time_ns
            assert het.energy_pj == hom.energy_pj
            assert het.power_mw == hom.power_mw

    def test_island_space_contains_homogeneous_and_default(self):
        from repro.tune import default_space, get_workload, island_ladder
        w = get_workload("expf")
        space = default_space(w, SNITCH_CLUSTER, heterogeneous=True)
        assert space.default in space
        assert space.default.islands == ()
        layouts = set(space.knob("islands").values)
        for p in SNITCH_CLUSTER.operating_points:
            assert (p.name,) in layouts
        assert () in layouts
        assert island_ladder(SNITCH_CLUSTER) == space.knob("islands").values

    @pytest.mark.parametrize("cap", [None, 250.0])
    def test_het_operating_point_never_worse_than_homogeneous(self, cap):
        """Acceptance: same power cap, same objective — the heterogeneous
        search returns an operating plan at least as good as the
        homogeneous ladder's."""
        from repro.tune import select_operating_point
        hom = select_operating_point("expf", n_cores=8, power_cap_mw=cap,
                                     objective="edp", cache=False)
        het = select_operating_point("expf", n_cores=8, power_cap_mw=cap,
                                     objective="edp", cache=False,
                                     heterogeneous=True)
        assert het.best_cost.edp <= hom.best_cost.edp
        if cap is not None:
            assert het.best_cost.power_mw <= cap

    def test_candidate_round_trips_island_tuple(self):
        import json

        from repro.tune import Candidate
        c = Candidate(block=64, n_cores=8,
                      islands=("1.45GHz@1.00V", "0.50GHz@0.60V"),
                      strategy="lpt")
        back = Candidate.from_dict(json.loads(json.dumps(c.to_dict())))
        assert back == c
        assert isinstance(back.islands, tuple)

    def test_more_islands_than_cores_drops_surplus(self):
        from repro.tune import Candidate, evaluate, get_workload
        w = get_workload("expf")
        narrow = evaluate(w, Candidate(block=w.max_block, n_cores=1,
                                       islands=("1.45GHz@1.00V",
                                                "0.50GHz@0.60V"),
                                       strategy="lpt"))
        single = evaluate(w, Candidate(block=w.max_block, n_cores=1,
                                       islands=("1.45GHz@1.00V",),
                                       strategy="lpt"))
        assert narrow == single
