"""``repro.obs.attrib`` — differential cycle attribution must be EXACT.

The contract: for any two traced evaluations (plan A vs plan B on one
target, or one kernel on Target A vs Target B), the waterfall's step
deltas — computed as exact ``Fraction``s over the recorded lane
aggregates — sum **bit-for-bit** to the ``Report`` cycle delta, with
every endpoint/side-consistency check green (``Attribution.exact``).
"No attribution" is a valid answer only as an exception, never as an
inexact waterfall.

Pinned here:

1. tuned-vs-default exactness for every simulatable+tunable kernel, on
   homogeneous and DVFS-island targets, under every scheduling strategy,
   for both the COPIFT and the rv32g-baseline decomposition;
2. Target-vs-Target attribution (the "what did the big.LITTLE layout
   buy" question);
3. per-block plan attribution for every tunable workload (including the
   tuner-only ones with no cluster Report);
4. a hypothesis property over random plan knobs: *any* pair of valid
   plans attributes exactly;
5. serialization (to_dict / from_dict / render_dict) preserving the
   exact verdict, and the ``Tuner.attribute`` front door.
"""

from dataclasses import replace
from fractions import Fraction

import pytest

from repro import api, obs
from repro.cluster.scheduler import STRATEGIES
from repro.obs.attrib import (Attribution, attribute_evaluate,
                              attribute_plans)
from repro.tune import default_space, get_workload
from tests._hypothesis_compat import given, settings, st

SIM_TUNABLE = ("expf", "logf", "pi_xoshiro128p")
ALL_WORKLOADS = ("expf", "logf", "montecarlo", "prng", "softmax")
HET_SPEC = "2@1.45GHz@1.00V,6@0.50GHz@0.60V"


def _workload(name):
    """Workload by name, resolving kernel names (``pi_xoshiro128p`` →
    ``montecarlo``) through the registry."""
    try:
        return get_workload(name)
    except KeyError:
        from repro.api.registry import kernel
        return kernel(name).get_workload()


def _tuned(name):
    """A plan that differs from the default without a tuner search:
    drop one block rung and flip fusion where the space allows it."""
    w = _workload(name)
    space = default_space(w)
    d = space.default
    blocks = space.knob("block").values
    block = blocks[-2] if len(blocks) > 1 else d.block
    return w, d, replace(d, block=block)


def _assert_exact(att):
    assert att.exact, [c for c in att.checks if not c["ok"]]
    total = sum((s.delta for s in att.steps), Fraction(0))
    assert total == Fraction(att.cycles_b) - Fraction(att.cycles_a)


class TestEvaluateAttribution:
    @pytest.mark.parametrize("name", SIM_TUNABLE)
    @pytest.mark.parametrize("which", ["copift", "base"])
    def test_plan_vs_plan_homogeneous(self, name, which):
        _, d, t = _tuned(name)
        att = attribute_evaluate(name, plan_a=d, plan_b=t, which=which)
        _assert_exact(att)
        assert att.kind == "evaluate" and att.which == which
        # the endpoints are the actual Reports' cycle figures
        field = f"cycles_{which}"
        assert att.cycles_a == getattr(att.report_a, field)
        assert att.cycles_b == getattr(att.report_b, field)

    @pytest.mark.parametrize("name", SIM_TUNABLE)
    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_plan_vs_plan_het_all_strategies(self, name, strategy):
        target = api.Target.heterogeneous(HET_SPEC, strategy=strategy)
        _, d, t = _tuned(name)
        for which in ("copift", "base"):
            att = attribute_evaluate(name, target, target,
                                     plan_a=d, plan_b=t, which=which)
            _assert_exact(att)

    @pytest.mark.parametrize("name", ["expf", "logf", "poly_lcg", "pi_lcg",
                                      "poly_xoshiro128p", "pi_xoshiro128p"])
    def test_every_simulatable_kernel_target_vs_target(self, name):
        """Every registered simulatable kernel attributes exactly — the
        non-tunable ones (no plan space) through the Target-vs-Target
        door, both decompositions sharing one pair of traces."""
        from repro.obs.attrib import attribute
        a = api.Target.homogeneous(n_cores=8)
        b = api.Target.heterogeneous(HET_SPEC)
        with obs.session() as sa:
            rep_a = api.evaluate(name, a)
        with obs.session() as sb:
            rep_b = api.evaluate(name, b)
        for which in ("copift", "base"):
            _assert_exact(attribute(sa.recorder, sb.recorder, rep_a, rep_b,
                                    which=which))

    @pytest.mark.parametrize("which", ["copift", "base"])
    def test_target_vs_target(self, which):
        """Homogeneous vs big.LITTLE: the schedule step carries the
        frequency/blocks move, and the waterfall still telescopes."""
        a = api.Target.homogeneous(n_cores=8)
        b = api.Target.heterogeneous(HET_SPEC)
        att = attribute_evaluate("expf", a, b, which=which,
                                 label_a="hom8", label_b="big.LITTLE")
        _assert_exact(att)
        assert att.label_a == "hom8" and att.label_b == "big.LITTLE"
        assert any(s.name == "schedule" for s in att.steps)

    def test_serialized_vs_pipelined_plan(self):
        """pipelined=False (Fig. 1f) vs the default: the dual-issue
        overlap step explains the difference between sum- and
        max-combined phases, exactly."""
        w = get_workload("logf")
        d = default_space(w).default
        serial = replace(d, pipelined=False)
        att = attribute_evaluate("logf", plan_a=serial, plan_b=d)
        _assert_exact(att)
        assert att.cycles_b <= att.cycles_a  # overlap never hurts
        overlap = [s for s in att.steps if s.name == "dual_issue_overlap"]
        assert overlap and overlap[0].delta <= 0

    def test_identity_attribution_is_all_zeros(self):
        w = get_workload("expf")
        d = default_space(w).default
        att = attribute_evaluate("expf", plan_a=d, plan_b=d)
        _assert_exact(att)
        assert att.delta == 0
        assert all(s.delta == 0 for s in att.steps)

    def test_island_plans_rejected(self):
        w = get_workload("expf")
        d = default_space(w).default
        bad = replace(d, islands=(("1.00GHz", 4),))
        with pytest.raises(ValueError, match="island"):
            attribute_evaluate("expf", plan_a=d, plan_b=bad)


class TestPlanAttribution:
    @pytest.mark.parametrize("name", ALL_WORKLOADS)
    def test_all_workloads_exact(self, name):
        """Per-block attribution covers the tuner-only workloads too
        (softmax, prng: no ISA baseline, no cluster Report)."""
        w, d, t = _tuned(name)
        att = attribute_plans(w, d, t)
        _assert_exact(att)
        assert att.kind == "plan"
        assert att.meta["block_a"] == d.block
        assert att.meta["block_b"] == t.block

    def test_accepts_workload_name(self):
        _, d, t = _tuned("softmax")
        att = attribute_plans("softmax", d, t)
        _assert_exact(att)

    @settings(max_examples=15, deadline=None)
    @given(name=st.sampled_from(ALL_WORKLOADS),
           block_idx_a=st.integers(0, 7), block_idx_b=st.integers(0, 7),
           fuse_a=st.booleans(), fuse_b=st.booleans(),
           pipe_a=st.booleans(), pipe_b=st.booleans())
    def test_property_random_plan_pairs_exact(self, name, block_idx_a,
                                              block_idx_b, fuse_a, fuse_b,
                                              pipe_a, pipe_b):
        """ANY pair of valid plans attributes exactly — including
        serialized-vs-pipelined crossings, where the waterfall walks
        through the serialized sandwich."""
        w = get_workload(name)
        space = default_space(w)
        blocks = space.knob("block").values
        d = space.default
        a = replace(d, block=blocks[block_idx_a % len(blocks)],
                    fuse_fp=fuse_a, pipelined=pipe_a)
        b = replace(d, block=blocks[block_idx_b % len(blocks)],
                    fuse_fp=fuse_b, pipelined=pipe_b)
        _assert_exact(attribute_plans(w, a, b))


class TestAttributionObject:
    def _any(self):
        _, d, t = _tuned("logf")
        return attribute_evaluate("logf", plan_a=d, plan_b=t)

    def test_to_dict_json_roundtrip_preserves_exact(self):
        import json
        att = self._any()
        doc = json.loads(json.dumps(att.to_dict()))
        assert doc["exact"] is True
        back = Attribution.from_dict(doc)
        _assert_exact(back)
        assert back.cycles_a == att.cycles_a
        assert [s.name for s in back.steps] == [s.name for s in att.steps]
        assert all(sa.delta == sb.delta
                   for sa, sb in zip(att.steps, back.steps))

    def test_render_and_render_dict_agree(self):
        att = self._any()
        text = att.render()
        assert "exact=True" in text and att.kernel in text
        assert Attribution.render_dict(att.to_dict()) == text

    def test_speedup_and_delta(self):
        att = self._any()
        assert att.delta == att.cycles_b - att.cycles_a
        assert att.speedup == pytest.approx(att.cycles_a / att.cycles_b)


class TestTunerAttribute:
    def test_simulatable_kernel_goes_through_reports(self):
        att = api.Tuner().attribute("expf")
        _assert_exact(att)
        assert att.kind == "evaluate"
        assert att.label_a == "default" and att.label_b == "tuned"
        assert "predicted_speedup" in att.meta

    def test_tuner_only_kernel_goes_through_blocks(self):
        att = api.Tuner().attribute("softmax")
        _assert_exact(att)
        assert att.kind == "plan"

    def test_accepts_precomputed_result(self):
        tuner = api.Tuner()
        res = tuner.plan("softmax")
        att = tuner.attribute("softmax", result=res)
        _assert_exact(att)
        assert att.meta["plan_b"] == res.best.to_dict()
