"""Unit tests for the ``benchmarks/run.py --diff`` perf-trajectory tool
over two synthetic ``BENCH_*.json`` snapshots."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from benchmarks.run import diff_snapshots, format_diff

REPO = Path(__file__).resolve().parent.parent


def _snapshot(lines_by_section):
    return {"schema": 1,
            "sections": {name: {"lines": lines, "data": None, "error": None}
                         for name, lines in lines_by_section.items()}}


SNAP_A = _snapshot({
    "fig2": ["fig2.expf,speedup,1.50", "fig2.logf,speedup,1.30"],
    "cluster": ["cluster.expf,8,1.00GHz@0.80V,1.40,200.0",
                "cluster.expf,16,1.00GHz@0.80V,1.35,400.0"],
})
SNAP_B = _snapshot({
    "fig2": ["fig2.expf,speedup,1.50",          # unchanged
             "fig2.logf,speedup,1.10"],          # regressed ~15%
    "cluster": ["cluster.expf,8,1.00GHz@0.80V,1.40,210.0",  # power +5%
                "cluster.expf,16,1.00GHz@0.80V,1.35,400.0",
                "cluster.het.expf,lpt,1.45"],     # new row
})


class TestDiffSnapshots:
    def test_identical_snapshots_report_nothing(self):
        doc = diff_snapshots(SNAP_A, SNAP_A)
        assert doc["changed"] == []
        assert doc["only_in_a"] == [] and doc["only_in_b"] == []
        assert doc["n_compared"] == 4
        assert any("identical" in line for line in format_diff(doc))

    def test_moved_fields_surface_with_relative_delta(self):
        doc = diff_snapshots(SNAP_A, SNAP_B, threshold=0.02)
        changed = {(r["section"], r["key"]): r for r in doc["changed"]}
        # logf speedup 1.30 -> 1.10: ~15% move, reported
        key = ("fig2", "fig2.logf,speedup")
        assert key in changed
        assert changed[key]["a"] == 1.30 and changed[key]["b"] == 1.10
        assert changed[key]["rel_delta"] == pytest.approx(0.2 / 1.3)
        # power 200 -> 210: 5% move, reported
        assert ("cluster", "cluster.expf,1.00GHz@0.80V") in changed

    def test_threshold_suppresses_small_moves(self):
        doc = diff_snapshots(SNAP_A, SNAP_B, threshold=0.10)
        keys = {(r["section"], r["key"]) for r in doc["changed"]}
        assert ("fig2", "fig2.logf,speedup") in keys        # 15% > 10%
        assert ("cluster", "cluster.expf,1.00GHz@0.80V") not in keys  # 5%

    def test_added_and_removed_lines(self):
        doc = diff_snapshots(SNAP_A, SNAP_B)
        assert doc["only_in_b"] == ["cluster:cluster.het.expf,lpt"]
        assert doc["only_in_a"] == []
        rev = diff_snapshots(SNAP_B, SNAP_A)
        assert rev["only_in_a"] == ["cluster:cluster.het.expf,lpt"]

    def test_unchanged_matching_is_occurrence_aware(self):
        """Two lines with the same textual key (differing only in numeric
        columns) diff positionally, not first-match."""
        a = _snapshot({"s": ["k,1.0", "k,2.0"]})
        b = _snapshot({"s": ["k,1.0", "k,3.0"]})
        doc = diff_snapshots(a, b)
        assert len(doc["changed"]) == 1
        assert doc["changed"][0]["occurrence"] == 1
        assert doc["changed"][0]["a"] == 2.0
        assert doc["changed"][0]["b"] == 3.0

    def test_zero_baseline_reports_infinite_delta(self):
        a = _snapshot({"s": ["k,0.0"]})
        b = _snapshot({"s": ["k,5.0"]})
        doc = diff_snapshots(a, b)
        assert doc["changed"][0]["rel_delta"] == float("inf")

    def test_repeat_count_change_reports_shape_not_bogus_deltas(self):
        """Dropping one row of a repeated key (e.g. removing a core count
        from a sweep) must not positionally cross-match the survivors
        against unrelated rows: the group is flagged as shape-changed and
        excluded from per-field comparison."""
        a = _snapshot({"s": ["k,1,100.0", "k,2,200.0", "other,7.0"]})
        b = _snapshot({"s": ["k,2,200.0", "other,7.0"]})
        doc = diff_snapshots(a, b)
        assert doc["changed"] == []
        assert doc["shape_changed"] == ["s:k"]
        assert doc["only_in_a"] == [] and doc["only_in_b"] == []
        assert doc["n_compared"] == 1          # just the 'other' row
        assert any(line.startswith("diff.shape_changed,s:k")
                   for line in format_diff(doc))


class TestCli:
    def test_diff_cli_end_to_end(self, tmp_path):
        pa, pb = tmp_path / "A.json", tmp_path / "B.json"
        pa.write_text(json.dumps(SNAP_A))
        pb.write_text(json.dumps(SNAP_B))
        out = subprocess.run(
            [sys.executable, str(REPO / "benchmarks" / "run.py"),
             "--diff", str(pa), str(pb)],
            capture_output=True, text=True, cwd=REPO, check=True)
        assert "diff.changed,fig2,fig2.logf,speedup" in out.stdout
        assert "diff.added,cluster:cluster.het.expf,lpt" in out.stdout

    def test_diff_rejects_missing_file(self, tmp_path):
        out = subprocess.run(
            [sys.executable, str(REPO / "benchmarks" / "run.py"),
             "--diff", str(tmp_path / "nope.json"),
             str(tmp_path / "nope2.json")],
            capture_output=True, text=True, cwd=REPO)
        assert out.returncode != 0
        assert "cannot read snapshot" in out.stderr

    def test_fail_on_shape_gates_shape_changes_only(self, tmp_path):
        """The CI gate: --fail-on-shape exits 1 when lines appear/vanish
        (SNAP_B adds a het row), but numeric drift alone passes."""
        pa, pb = tmp_path / "A.json", tmp_path / "B.json"
        pa.write_text(json.dumps(SNAP_A))
        pb.write_text(json.dumps(SNAP_B))
        out = subprocess.run(
            [sys.executable, str(REPO / "benchmarks" / "run.py"),
             "--diff", str(pa), str(pb), "--fail-on-shape"],
            capture_output=True, text=True, cwd=REPO)
        assert out.returncode == 1
        assert "diff.fail" in out.stdout
        # Pure numeric drift (same shape): exit 0.
        drift = _snapshot({
            "fig2": ["fig2.expf,speedup,1.60", "fig2.logf,speedup,1.30"],
            "cluster": ["cluster.expf,8,1.00GHz@0.80V,1.40,200.0",
                        "cluster.expf,16,1.00GHz@0.80V,1.35,400.0"],
        })
        pc = tmp_path / "C.json"
        pc.write_text(json.dumps(drift))
        out = subprocess.run(
            [sys.executable, str(REPO / "benchmarks" / "run.py"),
             "--diff", str(pa), str(pc), "--fail-on-shape"],
            capture_output=True, text=True, cwd=REPO, check=True)
        assert "diff.changed" in out.stdout

    def test_fail_on_shape_allows_entirely_new_sections(self, tmp_path):
        """A section the baseline has no entry for is growth, not a
        regression: the gate reports it (diff.new_section) but exits 0 —
        otherwise every PR adding a benchmark section would be
        deterministically red with nothing in the PR able to fix it.
        A new line inside an *existing* section still fails (previous
        test)."""
        pa, pb = tmp_path / "A.json", tmp_path / "B.json"
        pa.write_text(json.dumps(SNAP_A))
        grown = json.loads(json.dumps(SNAP_A))
        grown["sections"]["perf"] = {
            "lines": ["perf.oracle.softmax,1200,32,37.6,4240.1,112.7,True"]}
        pb.write_text(json.dumps(grown))
        out = subprocess.run(
            [sys.executable, str(REPO / "benchmarks" / "run.py"),
             "--diff", str(pa), str(pb), "--fail-on-shape"],
            capture_output=True, text=True, cwd=REPO, check=True)
        assert "diff.new_section,perf,advisory_no_baseline" in out.stdout
        assert "diff.fail" not in out.stdout
        # A baseline section that recorded NO lines (skipped/errored, e.g.
        # roofline without dry-run artifacts) is no baseline either:
        # its first real lines are growth, not a shape regression.
        skipped = json.loads(json.dumps(SNAP_A))
        skipped["sections"]["perf"] = {"lines": [], "error": "skipped"}
        pa2 = tmp_path / "A2.json"
        pa2.write_text(json.dumps(skipped))
        out = subprocess.run(
            [sys.executable, str(REPO / "benchmarks" / "run.py"),
             "--diff", str(pa2), str(pb), "--fail-on-shape"],
            capture_output=True, text=True, cwd=REPO, check=True)
        assert "diff.new_section,perf,advisory_no_baseline" in out.stdout
        assert "diff.fail" not in out.stdout

    def test_fail_on_shape_catches_column_level_changes(self, tmp_path):
        """Regression: a numeric column added/vanished inside a surviving
        line is a shape change too (documented contract)."""
        a = _snapshot({"s": ["k,1.0"]})
        b = _snapshot({"s": ["k,1.0,0.5"]})      # extra column, same key
        pa, pb = tmp_path / "A.json", tmp_path / "B.json"
        pa.write_text(json.dumps(a))
        pb.write_text(json.dumps(b))
        out = subprocess.run(
            [sys.executable, str(REPO / "benchmarks" / "run.py"),
             "--diff", str(pa), str(pb), "--fail-on-shape"],
            capture_output=True, text=True, cwd=REPO)
        assert out.returncode == 1
        assert "diff.fail" in out.stdout

    def test_fail_on_shape_requires_diff(self, tmp_path):
        out = subprocess.run(
            [sys.executable, str(REPO / "benchmarks" / "run.py"),
             "--fail-on-shape"],
            capture_output=True, text=True, cwd=REPO)
        assert out.returncode != 0
        assert "--fail-on-shape only applies to --diff" in out.stderr


class TestRooflineSection:
    """S2: the seed-era roofline section must skip gracefully when the
    TPU dry-run artifacts don't exist (they never do in this repo)."""

    def test_run_raises_filenotfound_without_artifacts(self, tmp_path,
                                                       monkeypatch):
        from benchmarks import roofline
        monkeypatch.setattr(roofline, "DRYRUN_DIR", str(tmp_path / "none"))
        with pytest.raises(FileNotFoundError, match="dry-run artifacts"):
            roofline.run()

    def test_main_skips_gracefully(self, tmp_path, monkeypatch, capsys):
        from benchmarks import roofline
        monkeypatch.setattr(roofline, "DRYRUN_DIR", str(tmp_path / "none"))
        assert roofline.main() == 0
        out = capsys.readouterr().out
        assert out.startswith("roofline.skipped,missing_artifact,")

    def test_harness_records_skip_with_empty_lines(self, tmp_path):
        """`benchmarks.run --sections roofline` exits 0, prints the skip
        line, and the snapshot carries lines=[] (no baseline for the
        shape gate) with the reason in `error`."""
        snap = tmp_path / "snap.json"
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.run",
             "--sections", "roofline", "--json", str(snap)],
            capture_output=True, text=True, cwd=REPO, check=True)
        assert "roofline.skipped,missing_artifact," in out.stdout
        entry = json.loads(snap.read_text())["sections"]["roofline"]
        assert entry["lines"] == []
        assert "missing_artifact" in entry["error"]

    def test_run_prices_synthetic_artifact(self, tmp_path, monkeypatch):
        """With one synthetic dry-run artifact in place the section still
        produces its table (the analysis path isn't dead code)."""
        from benchmarks import roofline
        rec = {
            "arch": "olmo-1b", "shape": "train_4k", "mesh": "pod",
            "devices": 8, "n_active_params": 1.0e9,
            "collectives": {"total_bytes": 4.0e9, "counts": {"all-reduce": 2}},
            "cost": {"flops": 1.0e15},
            "memory": {"total_bytes": 8 * 2**30},
        }
        d = tmp_path / "dryrun"
        d.mkdir()
        (d / "cell.json").write_text(json.dumps(rec))
        monkeypatch.setattr(roofline, "DRYRUN_DIR", str(d))
        lines = roofline.run()
        assert lines[0].startswith("roofline.arch,")
        row = lines[1].split(",")
        assert row[0] == "roofline.olmo-1b" and row[1] == "train_4k"
        assert row[5] in ("compute", "memory", "collective")
        assert lines[-1].startswith("roofline.multipod_cells_compiled,0,")
