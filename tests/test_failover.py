"""Serving failover (``serve.simulate(faults=..., retry=...)``): the
no-fault path stays bit-for-bit the healthy loop, the fault loop replays
deterministically, kill/retry/lost accounting conserves requests, the
retry policy's attempt/deadline bounds hold, ``FailoverPolicy`` headroom
rounds to valid slot counts, and an all-dead machine drains instead of
hanging.
"""

import math

import pytest

from repro import obs
from repro.resilience.failover import _slot_divisor
from repro.serve import (FailoverPolicy, RetryPolicy, Request, ServicePricer,
                         SloSpec, SlotPlan, StaticPolicy, Trace, make_faults,
                         make_trace, simulate)

PLAN = SlotPlan(n_slots=4, point="1.00GHz@0.80V", batch_max=1)
SLO = SloSpec(latency_ms=25.0)


def _trace(arrivals, elems=65536, kernel="softmax", duration_ms=20.0):
    """A hand-built deterministic trace — arrivals exactly where the test
    needs them (softmax@65536 services in ~1.5 ms on a 2-core slot)."""
    reqs = tuple(Request(rid=i, t_arrival_ms=float(t), kernel=kernel,
                         elems=elems)
                 for i, t in enumerate(arrivals))
    return Trace(spec="handmade", seed=0, duration_ms=duration_ms,
                 requests=reqs)


def _run(trace, faults, *, retry=None, policy=None, **kw):
    return simulate(trace, policy or StaticPolicy(plan=PLAN), slo=SLO,
                    pricer=kw.pop("pricer", None) or ServicePricer(),
                    epoch_ms=5.0, queue_cap=64, faults=faults,
                    retry=retry, **kw)


class TestRetryPolicy:

    def test_delay_is_exponential(self):
        r = RetryPolicy(base_delay_ms=0.5, backoff=2.0)
        assert [r.delay_ms(a) for a in (1, 2, 3)] == [0.5, 1.0, 2.0]

    @pytest.mark.parametrize("kw,msg", [
        (dict(max_attempts=0), "max_attempts"),
        (dict(timeout_ms=0.0), "timeout_ms"),
        (dict(backoff=0.5), "backoff"),
        (dict(base_delay_ms=-1.0), "base_delay_ms"),
    ])
    def test_validation(self, kw, msg):
        with pytest.raises(ValueError, match=msg):
            RetryPolicy(**kw)


class TestFailoverPolicy:

    def test_slot_divisor(self):
        assert _slot_divisor(8, 5) == 8
        assert _slot_divisor(8, 4) == 4
        assert _slot_divisor(8, 3) == 4
        assert _slot_divisor(8, 99) == 8
        assert _slot_divisor(6, 4) == 6
        assert _slot_divisor(8, 0) == 1

    def test_headroom_bumps_slots(self):
        trace = _trace([0.0])
        rep = _run(trace, make_faults(""), policy=FailoverPolicy(
            StaticPolicy(plan=PLAN), headroom_slots=1))
        assert rep.policy == "failover(static+1)"
        healthy = _run(trace, make_faults(""))
        # 4+1 slots rounds to 8 slots of 1 core: slower single-request
        # service than the 2-core slots the bare plan buys.
        assert rep.latency_ms["p50"] > healthy.latency_ms["p50"]

    def test_zero_headroom_is_passthrough(self):
        trace = _trace([0.0, 1.0])
        rep = _run(trace, make_faults(""), policy=FailoverPolicy(
            StaticPolicy(plan=PLAN), headroom_slots=0))
        base = _run(trace, make_faults(""))
        assert rep.latencies_ms == base.latencies_ms

    def test_negative_headroom_rejected(self):
        with pytest.raises(ValueError, match="headroom_slots"):
            FailoverPolicy(StaticPolicy(plan=PLAN), headroom_slots=-1)


class TestNoFaultPin:

    def test_empty_trace_routes_to_healthy_loop(self):
        """``faults`` without fail-stop events must not even enter the
        failover loop — the report is the healthy loop's, field for
        field.  (Window-only traces degrade the *evaluate* path, not the
        serving loop.)"""
        trace = make_trace("poisson:rate=900,kernel=softmax,elems=65536",
                           duration_ms=100.0, seed=4)
        pricer = ServicePricer()
        kw = dict(slo=SLO, pricer=pricer, epoch_ms=5.0, queue_cap=64)
        base = simulate(trace, StaticPolicy(plan=PLAN), **kw)
        for spec in ("", "throttle@5-20:isl0>0.6GHz,hbm@10-15:0.5x"):
            faulted = simulate(trace, StaticPolicy(plan=PLAN),
                               faults=make_faults(spec, duration_ms=100.0),
                               **kw)
            assert faulted == base
        assert base.n_failed == base.n_lost == base.failovers == 0

    def test_failover_loop_is_deterministic(self):
        trace = make_trace("poisson:rate=1200,kernel=softmax,elems=65536",
                           duration_ms=100.0, seed=9)
        faults = make_faults("corefail@20:c0.0,corefail@40:c0.5",
                             duration_ms=100.0)
        retry = RetryPolicy(max_attempts=3, timeout_ms=25.0)
        a = _run(trace, faults, retry=retry)
        b = _run(trace, faults, retry=retry)
        assert a == b


class TestKillAccounting:

    FAULT = "corefail@0.5:c0.0"   # lands mid-flight in the first batch

    def test_kill_then_retry_completes(self):
        trace = _trace([0.0, 0.0, 0.0, 0.0])
        rep = _run(trace, make_faults(self.FAULT, duration_ms=20.0),
                   retry=RetryPolicy(max_attempts=3, base_delay_ms=0.5))
        assert rep.n_failed == 1
        assert rep.n_retried == 1
        assert rep.n_lost == 0
        assert rep.n_completed == 4 and rep.completed_frac == 1.0
        assert rep.failovers == 1
        # The retried request paid the kill + backoff: its latency tops
        # the healthy ones.
        assert rep.max_latency_ms > 1.5 * min(rep.latencies_ms)

    def test_naive_mode_loses_killed_requests(self):
        trace = _trace([0.0, 0.0, 0.0, 0.0])
        rep = _run(trace, make_faults(self.FAULT, duration_ms=20.0),
                   retry=None)
        assert rep.n_failed == 1 and rep.n_retried == 0
        assert rep.n_lost == 1
        assert rep.n_completed == 3
        assert rep.completed_frac == pytest.approx(0.75)
        assert not rep.slo_met              # a lost request is a violation
        assert rep.slo_violations >= 1

    def test_attempt_budget_exhausts(self):
        trace = _trace([0.0, 0.0, 0.0, 0.0])
        rep = _run(trace, make_faults(self.FAULT, duration_ms=20.0),
                   retry=RetryPolicy(max_attempts=1))
        assert rep.n_retried == 0 and rep.n_lost == 1

    def test_deadline_abandons_late_retries(self):
        trace = _trace([0.0, 0.0, 0.0, 0.0])
        rep = _run(trace, make_faults(self.FAULT, duration_ms=20.0),
                   retry=RetryPolicy(max_attempts=3, timeout_ms=0.8,
                                     base_delay_ms=0.5))
        # t_retry = 0.5 + 0.5 = 1.0 > 0.8 from arrival: abandoned.
        assert rep.n_retried == 0 and rep.n_lost == 1

    def test_requests_conserved(self):
        trace = make_trace("poisson:rate=1500,kernel=softmax,elems=65536",
                           duration_ms=150.0, seed=11)
        faults = make_faults("corefail@30:c0.0,corefail@30:c0.1,"
                             "clusterfail@90:c0", duration_ms=150.0)
        rep = _run(trace, faults,
                   retry=RetryPolicy(max_attempts=2, timeout_ms=40.0))
        assert (rep.n_completed + rep.n_dropped + rep.n_shed + rep.n_lost
                == rep.n_requests)

    def test_format_lines_carries_fault_line(self):
        trace = _trace([0.0, 0.0, 0.0, 0.0])
        rep = _run(trace, make_faults(self.FAULT, duration_ms=20.0),
                   retry=None)
        txt = "\n".join(rep.format_lines())
        assert "batches_killed=1" in txt and "lost=1" in txt
        healthy = _run(trace, make_faults(""))
        assert "batches_killed" not in "\n".join(healthy.format_lines())


class TestAllDead:

    def test_cluster_death_drains_the_queue(self):
        """Killing every core must terminate the loop with everything
        after the death lost — not deadlock waiting for capacity."""
        trace = _trace([0.0, 1.0, 6.0, 7.0], duration_ms=20.0)
        faults = make_faults("clusterfail@3:c0", duration_ms=20.0)
        rep = _run(trace, faults, retry=RetryPolicy(max_attempts=3))
        assert rep.n_completed + rep.n_lost == 4
        assert rep.n_lost >= 2                # the post-death arrivals
        assert not rep.slo_met

    def test_mid_batch_cluster_death(self):
        trace = _trace([0.0] * 8, duration_ms=20.0)
        faults = make_faults("clusterfail@0.5:c0", duration_ms=20.0)
        rep = _run(trace, faults, retry=RetryPolicy(max_attempts=3))
        assert rep.n_completed == 0
        assert rep.n_lost == 8
        assert math.isnan(rep.max_latency_ms)


class TestObs:

    def test_fault_lane_and_metrics(self):
        trace = _trace([0.0, 0.0, 0.0, 0.0])
        faults = make_faults("corefail@0.5:c0.0", duration_ms=20.0)
        with obs.session(trace=True, metrics=True) as s:
            _run(trace, faults, retry=RetryPolicy(max_attempts=3))
        lanes = {e[0] for e in s.recorder.events}
        assert "resilience.faults" in lanes
        names = [e[3] for e in s.recorder.events
                 if e[0] == "resilience.faults"]
        assert names == ["corefail:c0.0"]
        m = s.metrics()
        assert m["resilience.faults.injected"]["value"] == 1
        assert m["resilience.batches_killed"]["value"] == 1
        assert m["resilience.requests_retried"]["value"] == 1
        assert m["resilience.static.completed_frac"]["value"] == 1.0
