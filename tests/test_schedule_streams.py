"""COPIFT Steps 4–7: tiling/pipelining correctness + SSR stream fusion."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (AffineStream, BufferSpec, Domain, IndirectStream,
                        PhaseDef, PipelinePlan, allocate_ssrs, execute, fuse,
                        make_plan, max_block, stage_type1_to_type2)
from repro.core.isa import L1_BUDGET_DWORDS, NUM_SSRS
from repro.core.schedule import PhaseProgram, run_pipelined, run_serial


# ---------------------------------------------------------------------------
# Multi-buffering / software pipelining (Step 5)
# ---------------------------------------------------------------------------

def test_buffer_replicas_distance_plus_one():
    """Paper: 'replicas ... equals the distance between the subgraphs
    connected by the respective edge ... plus one' (w buffer: 3)."""
    b = BufferSpec("w", producer_phase=0, consumer_phase=2)
    assert b.distance == 2 and b.replicas == 3
    b = BufferSpec("ki", producer_phase=0, consumer_phase=1)
    assert b.replicas == 2


def test_pipeline_iteration_count_and_order():
    plan = PipelinePlan(n_phases=3,
                        phase_domains=[Domain.FP, Domain.INT, Domain.FP],
                        buffers=[], block=8, n_blocks=5)
    assert plan.n_pipeline_iters == 7
    # Steady-state iteration: FP phases (0, 2) precede INT phase 1 (Step 7:
    # FREP loops first so the sequencer overlaps the integer thread).
    active = plan.active_phases(3)
    assert [p for p, _ in active] == [0, 2, 1]
    # Block indices are staggered: phase p works block j'-p.
    assert dict(active) == {0: 3, 2: 1, 1: 2}


def _mk_exp_plan(block):
    """The paper's exponential kernel as a 3-phase COPIFT plan."""
    def fp0(x):
        z = x * np.float32(1.4426950408889634)
        kd = jnp.floor(z)
        return {"ki": kd, "w": z - kd}
    def int1(ki):
        # integer phase: exponent assembly 2^ki via bit ops
        e = (ki.astype(jnp.int32) + 127) << 23
        return {"s": jax.lax.bitcast_convert_type(e, jnp.float32)}
    def fp2(w, s):
        p = jnp.exp2(w)
        return {"y": p * s}
    return make_plan("exp3", [
        PhaseDef(fp0, Domain.FP, writes=("ki", "w"), extern_reads=("x",)),
        PhaseDef(int1, Domain.INT, reads=("ki",), writes=("s",)),
        PhaseDef(fp2, Domain.FP, reads=("w", "s"), extern_writes=("y",)),
    ], n_elements=0, block=block)


@pytest.mark.parametrize("n,block", [(64, 16), (96, 32), (128, 128), (40, 8)])
def test_pipelined_equals_serial_exp(n, block):
    plan = _mk_exp_plan(block)
    plan.pipeline.n_blocks = n // block
    x = jnp.linspace(-3.0, 3.0, n, dtype=jnp.float32)
    ext = {"x": x, "y": jnp.zeros_like(x)}
    o_serial = run_serial_like(plan, ext, pipelined=False)
    o_pipe = run_serial_like(plan, ext, pipelined=True)
    np.testing.assert_array_equal(o_serial["y"], o_pipe["y"])
    np.testing.assert_allclose(o_pipe["y"], np.exp(np.asarray(x)), rtol=2e-5)


def run_serial_like(plan, ext, pipelined):
    return execute(plan, ext, pipelined=pipelined)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(1, 4))
def test_pipelined_equals_serial_random_phase_chains(depth, blocks):
    """Property: for any linear chain of `depth` phases with buffers of all
    distances, the rotated multi-buffer schedule equals the serial one.
    This is exactly the replica-count invariant: with fewer than
    distance+1 replicas, an in-flight block would be overwritten."""
    phases = []
    rng = np.random.default_rng(depth * 10 + blocks)
    coefs = rng.normal(size=depth).astype(np.float32)

    def mk(i):
        c = coefs[i]
        if i == 0:
            return PhaseDef(lambda x, c=c: {"b0": x * c},
                            Domain.FP, writes=("b0",), extern_reads=("x",))
        if i == depth - 1:
            return PhaseDef(lambda c=c, **kw: {"y": kw[f"b{i-1}"] + c},
                            Domain.INT if i % 2 else Domain.FP,
                            reads=(f"b{i-1}",), extern_writes=("y",))
        return PhaseDef(lambda c=c, **kw: {f"b{i}": kw[f"b{i-1}"] * c},
                        Domain.INT if i % 2 else Domain.FP,
                        reads=(f"b{i-1}",), writes=(f"b{i}",))

    if depth == 1:
        phases = [PhaseDef(lambda x: {"y": x * coefs[0]}, Domain.FP,
                           extern_reads=("x",), extern_writes=("y",))]
    else:
        phases = [mk(i) for i in range(depth)]
    B = 8
    plan = make_plan("chain", phases, n_elements=B * blocks, block=B)
    x = jnp.arange(B * blocks, dtype=jnp.float32)
    ext = {"x": x, "y": jnp.zeros_like(x)}
    o1 = execute(plan, ext, pipelined=False)
    o2 = execute(plan, ext, pipelined=True)
    np.testing.assert_array_equal(o1["y"], o2["y"])


def test_max_block_matches_l1_budget():
    """Table I 'Max Block' logic: block * replica-slots * 8B fits L1."""
    for slots, expect in [(13, L1_BUDGET_DWORDS // 13),
                          (12, L1_BUDGET_DWORDS // 12),
                          (6, L1_BUDGET_DWORDS // 6)]:
        assert max_block(slots) == expect
        assert max_block(slots) * slots <= L1_BUDGET_DWORDS


# ---------------------------------------------------------------------------
# SSR streams (Step 6)
# ---------------------------------------------------------------------------

class TestStreams:
    def test_affine_stream_addresses(self):
        s = AffineStream("x", base=100, lengths=(4,), strides=(2,))
        assert list(np.asarray(s.addresses())) == [100, 102, 104, 106]

    def test_fuse_two_streams_interleaves(self):
        """Paper Fig. 1i: two 1-D streams over adjacent buffers fuse into
        one 2-D stream visiting (element, buffer) pairs."""
        a = AffineStream("a", base=0, lengths=(4,), strides=(1,))
        b = AffineStream("b", base=100, lengths=(4,), strides=(1,))
        f = fuse([a, b])
        assert f.lengths == (4, 2) and f.strides == (1, 100)
        got = list(np.asarray(f.addresses()))
        assert got == [0, 100, 1, 101, 2, 102, 3, 103]
        # Fused stream covers exactly the union of member addresses.
        want = sorted(list(np.asarray(a.addresses())) +
                      list(np.asarray(b.addresses())))
        assert sorted(got) == want

    def test_fuse_rejects_mismatched(self):
        a = AffineStream("a", base=0, lengths=(4,), strides=(1,))
        b = AffineStream("b", base=1, lengths=(8,), strides=(1,))
        with pytest.raises(ValueError):
            fuse([a, b])

    def test_expf_streams_fit_three_ssrs(self):
        """expf needs 6 logical streams (reads x,w,t / writes w,ki,y);
        fusion must fit them into the 3 SSRs (paper §II-A)."""
        B = 157
        reads = [AffineStream(n, base=i * 8 * B, lengths=(B,), strides=(1,))
                 for i, n in enumerate(("x", "w", "t"))]
        writes = [AffineStream(n, base=(3 + i) * 8 * B, lengths=(B,),
                               strides=(1,), write=True)
                  for i, n in enumerate(("w_out", "ki", "y"))]
        allocated = allocate_ssrs(reads + writes)
        assert len(allocated) <= NUM_SSRS

    def test_allocate_raises_when_unfusable(self):
        streams = [AffineStream(f"s{i}", base=i * 977, lengths=(7,),
                                strides=(3 + i,)) for i in range(5)]
        with pytest.raises(ValueError):
            allocate_ssrs(streams)

    def test_issr_occupies_dedicated_mover(self):
        idx = AffineStream("idx", base=0, lengths=(16,), strides=(1,))
        issr = IndirectStream("table", base=4096, index=idx)
        a = AffineStream("a", base=0, lengths=(16,), strides=(1,))
        b = AffineStream("b", base=128, lengths=(16,), strides=(1,))
        allocated = allocate_ssrs([issr, a, b])
        # a and b (base delta 128) fuse into one mover; ISSR stays separate.
        assert len(allocated) == 2
        assert any(isinstance(s, IndirectStream) for s in allocated)

    def test_type1_to_type2_staging(self):
        """Paper Fig. 1h: int thread prefetches dynamically-addressed data
        into a dense buffer the FP thread can stream affinely."""
        table = jnp.arange(100, dtype=jnp.float32) * 2.0
        addrs = jnp.array([5, 17, 3, 99])
        staged = stage_type1_to_type2(lambda a: table[a], addrs)
        np.testing.assert_array_equal(staged, table[addrs])
        out = AffineStream("staged", base=0, lengths=(4,), strides=(1,))
        assert out.n_elements == staged.shape[0]
