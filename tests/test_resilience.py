"""``repro.resilience`` — the fault model and graceful degradation.

Covers the spec grammar (including every rejection path — a typo'd fault
spec must fail loudly, not silently no-op), ``state_at`` accumulation
semantics, trace determinism, the empty-trace bit-for-bit pin on
``api.evaluate`` at cluster and system level, degraded pricing
(dead cores / throttle windows / HBM narrowing all make the model
*slower*, never faster), the all-dead error, and zero-speed survival
masks in ``cluster.scheduler.assign``.
"""

import math

import pytest

from repro.api import (AllCoresDeadError, FaultState, FaultTrace, Target,
                       evaluate, make_faults)
from repro.cluster.scheduler import STRATEGIES, assign
from repro.cluster.topology import SNITCH_CLUSTER
from repro.resilience import (degrade_cluster, degrade_system_hbm,
                              masked_speeds, resolve_state, throttled_point)
from repro.system import SystemConfig


# ---------------------------------------------------------------------------
# Spec grammar
# ---------------------------------------------------------------------------

class TestGrammar:

    def test_full_spec_parses(self):
        tr = make_faults("corefail@2:c0.3,throttle@5-20:isl1>0.6GHz,"
                         "hbm@10-15:0.5x,clusterfail@4:c1",
                         duration_ms=50.0, n_clusters=2,
                         cores_per_cluster=8)
        kinds = [ev.kind for ev in tr.events]
        assert kinds == ["corefail", "clusterfail", "throttle", "hbm"]
        corefail = tr.events[0]
        assert (corefail.cluster, corefail.core) == (0, 3)
        assert corefail.t_end_ms == math.inf
        throttle = tr.events[2]
        assert (throttle.t_ms, throttle.t_end_ms) == (5.0, 20.0)
        assert throttle.value == 0.6

    def test_empty_spec_is_eventless(self):
        assert make_faults("").events == ()
        assert FaultTrace.empty().state_at(99.0).is_trivial

    def test_mttf_spec(self):
        tr = make_faults("mttf=5ms", duration_ms=200.0, seed=3,
                         n_clusters=2, cores_per_cluster=4)
        assert tr.events, "MTTF 5ms over 200ms should sample some deaths"
        assert all(ev.kind == "corefail" for ev in tr.events)
        # No core dies twice.
        victims = [(ev.cluster, ev.core) for ev in tr.events]
        assert len(victims) == len(set(victims))

    @pytest.mark.parametrize("bad,msg", [
        ("meteor@2:c0.1", "unknown fault kind"),
        ("corefail@2", "missing ':<what>'"),
        ("corefail@2:c0", "corefail needs"),
        ("clusterfail@2:c0.1", "clusterfail takes"),
        ("corefail@x:c0.1", "bad time token"),
        ("throttle@9-5:isl0>0.6GHz", "bad time window"),
        ("throttle@5-9:isl0>0GHz", "throttle cap must be positive"),
        ("throttle@5-9:c0>0.6GHz", "bad throttle target"),
        ("hbm@5-9:1.5x", "HBM multiplier must be in"),
        ("hbm@5-9:half", "bad HBM multiplier"),
        ("mttf=40s", "expected 'mttf=<ms>ms'"),
        ("mttf=40ms,mttf=2ms", "duplicate mttf"),
        ("corefail@2:c9.0", "references cluster 9"),
        ("corefail@2:c0.99", "references core 99"),
    ])
    def test_rejections_name_the_problem(self, bad, msg):
        with pytest.raises(ValueError, match=msg):
            make_faults(bad, n_clusters=2, cores_per_cluster=8)

    def test_shape_validation(self):
        with pytest.raises(ValueError, match="duration_ms"):
            make_faults("", duration_ms=0.0)
        with pytest.raises(ValueError, match="n_clusters"):
            make_faults("", n_clusters=0)


# ---------------------------------------------------------------------------
# state_at semantics
# ---------------------------------------------------------------------------

class TestStateAt:

    TRACE = make_faults(
        "corefail@2:c0.3,clusterfail@5:c1,throttle@5-20:isl0>0.6GHz,"
        "throttle@10-15:isl0>0.5GHz,hbm@10-15:0.5x,hbm@12-14:0.8x",
        duration_ms=50.0, n_clusters=2, cores_per_cluster=8)

    def test_before_anything(self):
        assert self.TRACE.state_at(1.0).is_trivial

    def test_failstops_accumulate(self):
        s = self.TRACE.state_at(6.0)
        assert s.dead_cores == ((0, 3),)
        assert s.dead_clusters == (1,)
        assert s.core_dead(0, 3) and s.core_dead(1, 0)
        assert not s.core_dead(0, 0)

    def test_windows_end_failstops_do_not(self):
        s = self.TRACE.state_at(30.0)
        assert s.freq_caps == () and s.hbm_scale == 1.0
        assert s.dead_cores == ((0, 3),) and s.dead_clusters == (1,)

    def test_overlapping_throttles_take_min(self):
        assert self.TRACE.state_at(12.0).freq_cap(0) == 0.5
        assert self.TRACE.state_at(6.0).freq_cap(0) == 0.6
        assert self.TRACE.state_at(6.0).freq_cap(1) is None

    def test_overlapping_hbm_windows_multiply(self):
        assert self.TRACE.state_at(13.0).hbm_scale == pytest.approx(0.4)
        assert self.TRACE.state_at(11.0).hbm_scale == pytest.approx(0.5)

    def test_cluster_death_absorbs_core_deaths(self):
        tr = make_faults("corefail@1:c0.2,clusterfail@3:c0",
                         n_clusters=1, cores_per_cluster=8)
        s = tr.state_at(4.0)
        assert s.dead_clusters == (0,) and s.dead_cores == ()

    def test_resolve_state(self):
        assert resolve_state(None).is_trivial
        st = FaultState(dead_cores=((0, 1),))
        assert resolve_state(st) is st
        assert resolve_state(self.TRACE, 6.0) == self.TRACE.state_at(6.0)
        with pytest.raises(TypeError, match="FaultTrace or FaultState"):
            resolve_state("corefail@2:c0.3")


# ---------------------------------------------------------------------------
# Degradation mapping
# ---------------------------------------------------------------------------

class TestDegrade:

    def test_throttled_point_picks_fastest_rung_under_cap(self):
        ladder = SNITCH_CLUSTER.operating_points
        nominal = SNITCH_CLUSTER.nominal
        p = throttled_point(nominal, 0.8, ladder)
        assert p.freq_ghz == 0.75
        # Already-within-cap points are untouched (identity on health).
        assert throttled_point(nominal, 1.0, ladder) is nominal
        # A cap under the whole ladder clamps to the floor rung.
        assert throttled_point(nominal, 0.1, ladder).freq_ghz == \
            min(q.freq_ghz for q in ladder)

    def test_degrade_cluster_masks_and_repoints(self):
        pts = (SNITCH_CLUSTER.nominal,) * 4
        st = FaultState(dead_cores=((0, 2),), freq_caps=((0, 0.6),))
        points, alive = degrade_cluster(SNITCH_CLUSTER, pts, st)
        assert alive == (True, True, False, True)
        assert all(p.freq_ghz <= 0.6 for p in points)
        assert masked_speeds(points, alive) == (0.5, 0.5, 0.0, 0.5)

    def test_degrade_system_hbm(self):
        sysc = SystemConfig.homogeneous(2, SNITCH_CLUSTER,
                                        hbm_bytes_per_cycle=100.0)
        out = degrade_system_hbm(sysc, FaultState(hbm_scale=0.5))
        assert out.hbm_bytes_per_cycle == 50.0
        # Trivial scale is the identity (same object, not a copy).
        assert degrade_system_hbm(sysc, FaultState()) is sysc
        # An unconstrained port becomes a real one at the scaled
        # aggregate DMA width.
        free = SystemConfig.homogeneous(2, SNITCH_CLUSTER)
        out = degrade_system_hbm(free, FaultState(hbm_scale=0.5))
        assert out.hbm_bytes_per_cycle == \
            pytest.approx(free.aggregate_dma_bytes_per_cycle * 0.5)


# ---------------------------------------------------------------------------
# Zero-speed survival masks in the scheduler
# ---------------------------------------------------------------------------

class TestZeroSpeedAssign:

    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_dead_cores_get_zero_blocks(self, strategy):
        wa = assign(24, (1.0, 0.0, 1.0, 0.0), strategy)
        assert wa.blocks_per_core[1] == 0 and wa.blocks_per_core[3] == 0
        assert sum(wa.blocks_per_core) == 24
        # Survivors carry exactly what a 2-core assign would give them.
        inner = assign(24, (1.0, 1.0), strategy)
        assert (wa.blocks_per_core[0], wa.blocks_per_core[2]) == \
            tuple(inner.blocks_per_core)

    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_all_dead(self, strategy):
        with pytest.raises(ValueError, match="positive speed"):
            assign(8, (0.0, 0.0), strategy)
        # Zero work on a dead cluster is fine (idle clusters price as 0).
        wa = assign(0, (0.0, 0.0), strategy)
        assert tuple(wa.blocks_per_core) == (0, 0)

    def test_negative_speed_rejected(self):
        with pytest.raises(ValueError, match=">= 0"):
            assign(8, (1.0, -0.5), "block_cyclic")


# ---------------------------------------------------------------------------
# api.evaluate(faults=...)
# ---------------------------------------------------------------------------

class TestEvaluateFaults:

    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_empty_trace_bit_for_bit_cluster(self, strategy):
        t = Target(strategy=strategy)
        base = evaluate("expf", t, total_blocks=16)
        assert evaluate("expf", t, total_blocks=16,
                        faults=FaultTrace.empty()) == base
        assert evaluate("expf", t, total_blocks=16,
                        faults=make_faults("")) == base

    def test_empty_trace_bit_for_bit_system(self):
        t = Target.system(4, hbm_bytes_per_cycle=128.0)
        base = evaluate("montecarlo", t, total_blocks=64)
        faulted = evaluate("montecarlo", t, total_blocks=64,
                           faults=FaultTrace.empty())
        assert faulted == base

    def test_dead_cores_slow_the_cluster(self):
        t = Target()
        base = evaluate("expf", t, total_blocks=32)
        st = FaultState(dead_cores=((0, 0), (0, 1), (0, 2), (0, 3)))
        degraded = evaluate("expf", t, total_blocks=32, faults=st)
        assert degraded.cycles_copift > base.cycles_copift
        assert degraded.blocks_per_core[:4] == (0, 0, 0, 0)
        assert sum(degraded.blocks_per_core) == 32

    def test_throttle_slows_the_cluster(self):
        t = Target()
        base = evaluate("expf", t, total_blocks=32)
        st = FaultState(freq_caps=((0, 0.6),))
        degraded = evaluate("expf", t, total_blocks=32, faults=st)
        assert all(p.freq_ghz <= 0.6 for p in degraded.core_points)
        assert degraded.time_us > base.time_us

    def test_trace_sampling_at_time(self):
        tr = make_faults("corefail@10:c0.0,corefail@10:c0.1",
                         duration_ms=50.0)
        t = Target()
        before = evaluate("expf", t, total_blocks=32, faults=tr,
                          fault_t_ms=5.0)
        after = evaluate("expf", t, total_blocks=32, faults=tr,
                         fault_t_ms=15.0)
        assert before == evaluate("expf", t, total_blocks=32)
        assert after.cycles_copift > before.cycles_copift

    def test_dead_cluster_slows_the_system(self):
        t = Target.system(4, hbm_bytes_per_cycle=128.0)
        base = evaluate("montecarlo", t, total_blocks=64)
        degraded = evaluate("montecarlo", t, total_blocks=64,
                            faults=FaultState(dead_clusters=(1,)))
        assert degraded.cycles_copift > base.cycles_copift

    def test_hbm_degradation_is_monotone(self):
        t = Target.system(4, hbm_bytes_per_cycle=64.0)
        base = evaluate("montecarlo", t, total_blocks=128)
        narrow = evaluate("montecarlo", t, total_blocks=128,
                          faults=FaultState(hbm_scale=0.25))
        assert narrow.cycles_copift >= base.cycles_copift

    def test_all_dead_raises(self):
        st = FaultState(dead_clusters=(0,))
        with pytest.raises(AllCoresDeadError, match="no core alive"):
            evaluate("expf", Target(), faults=st)
        with pytest.raises(AllCoresDeadError):
            evaluate("montecarlo", Target.system(2),
                     faults=FaultState(dead_clusters=(0, 1)))

    def test_bad_faults_type(self):
        with pytest.raises(TypeError, match="FaultTrace or FaultState"):
            evaluate("expf", Target(), faults="corefail@2:c0.3")
